//! Study health: LOO-based GP diagnostics, a convergence ledger, and
//! anomaly flags with hysteresis.
//!
//! The flight recorder (PR 7) made the system *traceable*; this module
//! answers the operator's actual question — **is this study converging,
//! and is its model trustworthy?** A [`HealthLedger`] lives inside each
//! study actor and is appended on every committed ask/tell:
//!
//! - **GP diagnostics** — leave-one-out residuals/variances from
//!   [`crate::gp::GpRegressor::loo_diagnostics`] (O(n²) off the cached
//!   `w_half = L⁻ᵀ`, zero new factorizations), summarized into a mean
//!   LOO log-predictive density, max |z|, and 95% coverage.
//! - **Convergence ledger** — raw-units incumbent history,
//!   simple-regret deltas, trials-since-improvement, and the log-EI of
//!   accepted suggestions (EI-collapse detector).
//! - **QN quality** — per-restart iteration counts, stop-reason mix,
//!   and final projected-gradient ∞-norms from the MSO run behind each
//!   ask: the paper's C-BE-vs-D-BE degradation signature as a live
//!   metric instead of a post-hoc trace query.
//!
//! Everything here is **read-only with respect to the optimization
//! state**: no RNG draws, no GP mutation, no fit-schedule interaction —
//! suggestions stay bitwise-identical with the ledger on or off
//! (proven in `tests/chaos.rs`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::gp::kernel::GpParams;
use crate::gp::regressor::LooDiagnostics;
use crate::gp::stats::log_normal_pdf;
use crate::optim::mso::MsoResult;

// ---------------------------------------------------------------------
// Flag taxonomy (stable wire tokens — README "Health & watch").

/// A fitted hyperparameter is pinned at its MLL box bound: the fit
/// wanted to leave the search box, so the model family is fighting the
/// data (classic symptoms: noise at the floor → interpolating an
/// unrepeatable signal; lengthscale at the ceiling → flat posterior).
pub const FLAG_HYPERPARAM_AT_BOUND: &str = "hyperparam_at_bound";
/// Accepted suggestions carry log-EI below [`LOG_EI_COLLAPSE`]: the
/// acquisition surface has collapsed and asks are near-random.
pub const FLAG_EI_COLLAPSED: &str = "ei_collapsed";
/// No incumbent improvement for ≥ [`STALL_TRIALS`] tells.
pub const FLAG_STALLED: &str = "stalled";
/// LOO calibration is off: 95% coverage below [`MIN_COVERAGE95`] or a
/// standardized LOO residual beyond [`MAX_ABS_Z`].
pub const FLAG_MISCALIBRATED: &str = "miscalibrated";
/// ≥ [`QN_FAIL_FRAC`] of recent QN restarts stopped on a line-search or
/// numerical failure — the paper's coupled-update pathology, live.
pub const FLAG_QN_LINESEARCH_FAILING: &str = "qn_linesearch_failing";

/// All flags, in the order they are evaluated and reported.
pub const ALL_FLAGS: [&str; 5] = [
    FLAG_HYPERPARAM_AT_BOUND,
    FLAG_EI_COLLAPSED,
    FLAG_STALLED,
    FLAG_MISCALIBRATED,
    FLAG_QN_LINESEARCH_FAILING,
];

/// log-EI threshold below which an accepted suggestion counts as
/// collapsed (EI < e⁻³⁰ ≈ 1e-13 in standardized units).
pub const LOG_EI_COLLAPSE: f64 = -30.0;
/// Tells without incumbent improvement before `stalled` raises.
pub const STALL_TRIALS: u64 = 15;
/// Minimum LOO sample size before calibration flags are trusted.
pub const MIN_LOO_N: usize = 10;
/// 95%-interval empirical coverage below this is miscalibration.
pub const MIN_COVERAGE95: f64 = 0.6;
/// Any |z| beyond this is miscalibration (a ~5σ LOO surprise).
pub const MAX_ABS_Z: f64 = 5.0;
/// Fraction of the recent QN window failing line search to raise.
pub const QN_FAIL_FRAC: f64 = 0.5;
/// Minimum restarts in the window before the QN flag is trusted.
pub const MIN_QN_WINDOW: usize = 8;
/// Consecutive true evaluations before a flag raises.
const RAISE_AFTER: u32 = 2;
/// Consecutive false evaluations before a raised flag clears.
const CLEAR_AFTER: u32 = 3;
/// Rolling QN window length (restarts) and accepted-acq window.
const QN_WINDOW: usize = 64;
const ACQ_WINDOW: usize = 32;
/// Trailing window (tells) for the regret slope.
const SLOPE_WINDOW: u64 = 20;

// ---------------------------------------------------------------------
// LOO summary.

/// Aggregate view of one [`LooDiagnostics`] pass, raw target units
/// where units matter.
#[derive(Clone, Copy, Debug)]
pub struct LooSummary {
    /// Training points the diagnostics cover.
    pub n: usize,
    /// Mean LOO log predictive density in **raw** target units
    /// (standardized LPD minus ln σ_raw): comparable across studies.
    pub lpd: f64,
    /// Largest |standardized LOO residual|.
    pub max_abs_z: f64,
    /// Fraction of points inside the central 95% LOO interval.
    pub coverage95: f64,
}

impl LooSummary {
    /// Summarize raw diagnostics. `raw_sigma` is the standardizer's
    /// target scale (`Standardizer::std`), used to express the LPD in
    /// raw units. Returns `None` for an empty model.
    pub fn from_diagnostics(diag: &LooDiagnostics, raw_sigma: f64) -> Option<LooSummary> {
        let n = diag.residuals.len();
        if n == 0 {
            return None;
        }
        let mut lpd = 0.0;
        let mut max_abs_z = 0.0f64;
        let mut covered = 0usize;
        for (&e, &v) in diag.residuals.iter().zip(&diag.variances) {
            let sigma = v.max(1e-300).sqrt();
            let z = e / sigma;
            lpd += log_normal_pdf(z) - sigma.ln() - raw_sigma.max(1e-300).ln();
            max_abs_z = max_abs_z.max(z.abs());
            if z.abs() <= 1.959963984540054 {
                covered += 1;
            }
        }
        Some(LooSummary {
            n,
            lpd: lpd / n as f64,
            max_abs_z,
            coverage95: covered as f64 / n as f64,
        })
    }
}

/// True when any fitted hyperparameter sits within `tol` (in log space)
/// of its MLL search-box bound ([`GpParams::fit_bounds`]).
pub fn params_at_bound(p: &GpParams, tol: f64) -> bool {
    let theta = [p.log_len, p.log_sf2, p.log_noise];
    GpParams::fit_bounds()
        .iter()
        .zip(theta)
        .any(|(&(lo, hi), t)| (t - lo).abs() <= tol || (t - hi).abs() <= tol)
}

// ---------------------------------------------------------------------
// Per-ask MSO quality.

/// QN quality of one accepted suggestion, distilled from the MSO run
/// (the existing `qn_restart` telemetry, kept instead of dropped).
#[derive(Clone, Debug)]
pub struct AskQuality {
    pub trial_id: u64,
    /// log-EI of the accepted suggestion (MSO minimizes −logEI, so this
    /// is `−best_f`), standardized units.
    pub log_ei: f64,
    /// Per-restart QN iteration counts.
    pub iters: Vec<u32>,
    /// Per-restart evaluation counts (line-search probes included).
    pub evals: Vec<u32>,
    /// Per-restart final projected-gradient ∞-norms.
    pub grad_inf: Vec<f64>,
    /// Per-restart stop-reason tokens ([`crate::optim::StopReason::token`]).
    pub reasons: Vec<&'static str>,
}

impl AskQuality {
    pub fn from_mso(trial_id: u64, res: &MsoResult) -> AskQuality {
        AskQuality {
            trial_id,
            log_ei: -res.best_f,
            iters: res.restarts.iter().map(|r| r.iters as u32).collect(),
            evals: res.restarts.iter().map(|r| r.evals as u32).collect(),
            grad_inf: res.restarts.iter().map(|r| r.grad_inf).collect(),
            reasons: res.restarts.iter().map(|r| r.reason.token()).collect(),
        }
    }
}

/// Aggregated QN-health view over the rolling restart window plus
/// cumulative totals (the report payload).
#[derive(Clone, Debug)]
pub struct QnSummary {
    /// Restarts in the rolling window.
    pub window: usize,
    /// Restarts observed since this ledger was built.
    pub total: u64,
    pub median_iters: f64,
    pub grad_inf_p50: f64,
    pub grad_inf_p90: f64,
    /// Window fraction stopping on a converged reason (gradtol/ftol).
    pub converged_frac: f64,
    /// (stop-reason token, window count), every token listed.
    pub reasons: Vec<(&'static str, u64)>,
}

#[derive(Clone, Debug)]
struct QnRec {
    iters: u32,
    grad_inf: f64,
    reason: &'static str,
}

// ---------------------------------------------------------------------
// Hysteresis.

#[derive(Clone, Copy, Debug, Default)]
struct FlagState {
    on: bool,
    /// Consecutive evaluations agreeing with a pending transition.
    streak: u32,
}

impl FlagState {
    /// Feed one evaluation; returns `Some(new_state)` on a transition.
    fn step(&mut self, cond: bool) -> Option<bool> {
        if cond == self.on {
            self.streak = 0;
            return None;
        }
        self.streak += 1;
        let needed = if self.on { CLEAR_AFTER } else { RAISE_AFTER };
        if self.streak >= needed {
            self.on = cond;
            self.streak = 0;
            return Some(cond);
        }
        None
    }
}

// ---------------------------------------------------------------------
// The ledger.

/// Per-study convergence ledger + anomaly flags. Owned by the study
/// actor; all inputs are values already committed to the journal or
/// read-only views of the synced model, so maintaining it cannot
/// perturb suggestions.
#[derive(Debug, Default)]
pub struct HealthLedger {
    n_tells: u64,
    /// Raw-units incumbent (min) and the tell index that set it.
    best: Option<f64>,
    best_tell: u64,
    /// (tell index, incumbent after that tell) at each improvement.
    history: Vec<(u64, f64)>,
    since_improvement: u64,
    /// Last simple-regret delta (previous best − new best; 0 when the
    /// tell did not improve).
    last_delta: f64,
    /// (trial_id, log-EI) of recent accepted suggestions.
    acq: VecDeque<(u64, f64)>,
    qn: VecDeque<QnRec>,
    qn_total: u64,
    loo: Option<LooSummary>,
    gp_n_train: usize,
    model_at_bound: bool,
    flags: [FlagState; 5],
}

impl HealthLedger {
    pub fn new() -> HealthLedger {
        HealthLedger::default()
    }

    /// Record one committed tell. Pure function of the value stream —
    /// also used verbatim by journal replay so a restarted actor's
    /// convergence ledger matches a live one.
    pub fn on_tell(&mut self, value: f64) {
        self.n_tells += 1;
        let improved = self.best.is_none_or(|b| value < b);
        if improved {
            self.last_delta = self.best.map_or(0.0, |b| b - value);
            self.best = Some(value);
            self.best_tell = self.n_tells;
            self.history.push((self.n_tells, value));
            self.since_improvement = 0;
        } else {
            self.last_delta = 0.0;
            self.since_improvement += 1;
        }
    }

    /// Record the MSO quality of one committed ask (live asks only;
    /// replayed asks re-inject recorded points and never run MSO).
    pub fn on_ask(&mut self, q: &AskQuality) {
        self.acq.push_back((q.trial_id, q.log_ei));
        while self.acq.len() > ACQ_WINDOW {
            self.acq.pop_front();
        }
        for i in 0..q.iters.len() {
            self.qn.push_back(QnRec {
                iters: q.iters[i],
                grad_inf: q.grad_inf[i],
                reason: q.reasons[i],
            });
            self.qn_total += 1;
        }
        while self.qn.len() > QN_WINDOW {
            self.qn.pop_front();
        }
    }

    /// Refresh the model-dependent inputs (called with a read-only view
    /// of the study's GP after a committed ask/tell).
    pub fn observe_model(&mut self, at_bound: bool, loo: Option<LooSummary>, n_train: usize) {
        self.model_at_bound = at_bound;
        if loo.is_some() {
            self.loo = loo;
        }
        self.gp_n_train = n_train;
    }

    /// Re-evaluate every flag through its hysteresis gate; returns the
    /// transitions `(token, now_on)` that fired, for mirroring into the
    /// flight recorder.
    pub fn reeval_flags(&mut self) -> Vec<(&'static str, bool)> {
        let conds = [
            self.model_at_bound,
            self.acq.back().is_some_and(|&(_, lei)| lei < LOG_EI_COLLAPSE),
            self.n_tells >= STALL_TRIALS && self.since_improvement >= STALL_TRIALS,
            self.loo.is_some_and(|l| {
                l.n >= MIN_LOO_N && (l.coverage95 < MIN_COVERAGE95 || l.max_abs_z > MAX_ABS_Z)
            }),
            self.qn.len() >= MIN_QN_WINDOW && {
                let failing = self
                    .qn
                    .iter()
                    .filter(|r| r.reason == "linesearch" || r.reason == "numerical")
                    .count();
                failing as f64 >= QN_FAIL_FRAC * self.qn.len() as f64
            },
        ];
        let mut transitions = Vec::new();
        for (i, cond) in conds.into_iter().enumerate() {
            if let Some(on) = self.flags[i].step(cond) {
                transitions.push((ALL_FLAGS[i], on));
            }
        }
        transitions
    }

    /// Currently-raised flags, in [`ALL_FLAGS`] order.
    pub fn active_flags(&self) -> Vec<&'static str> {
        ALL_FLAGS
            .iter()
            .zip(&self.flags)
            .filter(|(_, s)| s.on)
            .map(|(&t, _)| t)
            .collect()
    }

    pub fn n_tells(&self) -> u64 {
        self.n_tells
    }

    /// Raw-units incumbent and the tell index that set it.
    pub fn best(&self) -> Option<(f64, u64)> {
        self.best.map(|b| (b, self.best_tell))
    }

    pub fn since_improvement(&self) -> u64 {
        self.since_improvement
    }

    pub fn last_delta(&self) -> f64 {
        self.last_delta
    }

    /// log-EI of the most recent accepted suggestion.
    pub fn last_log_ei(&self) -> Option<f64> {
        self.acq.back().map(|&(_, lei)| lei)
    }

    pub fn loo(&self) -> Option<LooSummary> {
        self.loo
    }

    pub fn gp_n_train(&self) -> usize {
        self.gp_n_train
    }

    /// Incumbent improvement per tell over the trailing window
    /// (`≥ 0`; larger = still improving, `0` = flat / too early).
    pub fn regret_slope(&self) -> f64 {
        let (Some(best), true) = (self.best, self.n_tells > 0) else {
            return 0.0;
        };
        let w = SLOPE_WINDOW.min(self.n_tells);
        if w == 0 {
            return 0.0;
        }
        let from = self.n_tells - w;
        // Incumbent as of tell `from`: last improvement at index ≤ from.
        let then = self
            .history
            .iter()
            .rev()
            .find(|&&(i, _)| i <= from)
            .map(|&(_, b)| b);
        match then {
            Some(then) => (then - best) / w as f64,
            // No incumbent yet at the window start: slope from the
            // first recorded incumbent.
            None => match self.history.first() {
                Some(&(i0, b0)) if self.n_tells > i0 => {
                    (b0 - best) / (self.n_tells - i0) as f64
                }
                _ => 0.0,
            },
        }
    }

    /// Aggregate the rolling QN window (None before any model-based ask).
    pub fn qn_summary(&self) -> Option<QnSummary> {
        if self.qn.is_empty() {
            return None;
        }
        let mut iters: Vec<f64> = self.qn.iter().map(|r| r.iters as f64).collect();
        let mut grads: Vec<f64> = self.qn.iter().map(|r| r.grad_inf).collect();
        let converged = self
            .qn
            .iter()
            .filter(|r| r.reason == "gradtol" || r.reason == "ftol")
            .count();
        let reasons = crate::optim::StopReason::all_tokens()
            .iter()
            .map(|&t| (t, self.qn.iter().filter(|r| r.reason == t).count() as u64))
            .collect();
        Some(QnSummary {
            window: self.qn.len(),
            total: self.qn_total,
            median_iters: quantile_of(&mut iters, 0.5),
            grad_inf_p50: quantile_of(&mut grads, 0.5),
            grad_inf_p90: quantile_of(&mut grads, 0.9),
            converged_frac: converged as f64 / self.qn.len() as f64,
            reasons,
        })
    }
}

/// In-place nearest-rank quantile of a small sample (deterministic:
/// total order via `total_cmp`).
fn quantile_of(xs: &mut [f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(f64::total_cmp);
    let rank = ((xs.len() as f64 * q).ceil() as usize).clamp(1, xs.len());
    xs[rank - 1]
}

// ---------------------------------------------------------------------
// Shared gauges: the lock-cheap mirror the `metrics` op reads without
// messaging the actor (prom `dbe_study_*` families).

/// NaN-encoded "absent" sentinel for gauge f64 bits.
const ABSENT: u64 = f64::NAN.to_bits();

/// Atomic per-study health gauges, shared between the actor thread
/// (writer) and the metrics renderers (readers).
#[derive(Debug)]
pub struct HealthGauges {
    loo_lpd: AtomicU64,
    regret_slope: AtomicU64,
    best: AtomicU64,
    stall: AtomicU64,
    flags: AtomicU64,
}

impl Default for HealthGauges {
    fn default() -> Self {
        HealthGauges {
            loo_lpd: AtomicU64::new(ABSENT),
            regret_slope: AtomicU64::new(0f64.to_bits()),
            best: AtomicU64::new(ABSENT),
            stall: AtomicU64::new(0),
            flags: AtomicU64::new(0),
        }
    }
}

impl HealthGauges {
    pub fn new() -> HealthGauges {
        HealthGauges::default()
    }

    /// Publish the current ledger view (actor thread, post-commit).
    pub fn publish(&self, ledger: &HealthLedger) {
        let lpd = ledger.loo().map_or(f64::NAN, |l| l.lpd);
        self.loo_lpd.store(lpd.to_bits(), Ordering::Relaxed);
        self.regret_slope.store(ledger.regret_slope().to_bits(), Ordering::Relaxed);
        let best = ledger.best().map_or(f64::NAN, |(b, _)| b);
        self.best.store(best.to_bits(), Ordering::Relaxed);
        self.stall.store(ledger.since_improvement(), Ordering::Relaxed);
        self.flags.store(ledger.active_flags().len() as u64, Ordering::Relaxed);
    }

    /// Mean LOO-LPD (`None` until a model has been diagnosed).
    pub fn loo_lpd(&self) -> Option<f64> {
        let v = f64::from_bits(self.loo_lpd.load(Ordering::Relaxed));
        (!v.is_nan()).then_some(v)
    }

    pub fn regret_slope(&self) -> f64 {
        f64::from_bits(self.regret_slope.load(Ordering::Relaxed))
    }

    pub fn best(&self) -> Option<f64> {
        let v = f64::from_bits(self.best.load(Ordering::Relaxed));
        (!v.is_nan()).then_some(v)
    }

    pub fn stall(&self) -> u64 {
        self.stall.load(Ordering::Relaxed)
    }

    pub fn flag_count(&self) -> u64 {
        self.flags.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn incumbent_history_and_stall_counting() {
        let mut l = HealthLedger::new();
        for v in [5.0, 4.0, 4.5, 4.5, 3.0] {
            l.on_tell(v);
        }
        assert_eq!(l.n_tells(), 5);
        assert_eq!(l.best(), Some((3.0, 5)));
        assert_eq!(l.since_improvement(), 0);
        assert_eq!(l.last_delta(), 1.0);
        l.on_tell(3.5);
        l.on_tell(9.0);
        assert_eq!(l.since_improvement(), 2);
        assert_eq!(l.history, vec![(1, 5.0), (2, 4.0), (5, 3.0)]);
    }

    #[test]
    fn regret_slope_is_improvement_per_tell() {
        let mut l = HealthLedger::new();
        // 10 tells: incumbent goes 10 → 0 linearly.
        for i in 0..10 {
            l.on_tell(10.0 - i as f64);
        }
        // Window covers all 10 tells; incumbent at window start is the
        // first recorded one (10.0), so slope = (10 − 1)/9.
        assert!((l.regret_slope() - 1.0).abs() < 1e-12, "{}", l.regret_slope());
        // Flat tail: slope decays toward zero.
        for _ in 0..30 {
            l.on_tell(100.0);
        }
        assert_eq!(l.regret_slope(), 0.0);
    }

    #[test]
    fn flags_raise_and_clear_with_hysteresis() {
        let mut l = HealthLedger::new();
        // One bad evaluation is not enough…
        l.observe_model(true, None, 10);
        assert!(l.reeval_flags().is_empty());
        assert!(l.active_flags().is_empty());
        // …the second raises (RAISE_AFTER = 2).
        let tr = l.reeval_flags();
        assert_eq!(tr, vec![(FLAG_HYPERPARAM_AT_BOUND, true)]);
        assert_eq!(l.active_flags(), vec![FLAG_HYPERPARAM_AT_BOUND]);
        // Clearing needs CLEAR_AFTER = 3 consecutive healthy evals.
        l.observe_model(false, None, 10);
        assert!(l.reeval_flags().is_empty());
        assert!(l.reeval_flags().is_empty());
        assert_eq!(l.reeval_flags(), vec![(FLAG_HYPERPARAM_AT_BOUND, false)]);
        assert!(l.active_flags().is_empty());
    }

    #[test]
    fn ei_collapse_and_stall_flags() {
        let mut l = HealthLedger::new();
        let q = AskQuality {
            trial_id: 0,
            log_ei: LOG_EI_COLLAPSE - 1.0,
            iters: vec![3],
            evals: vec![5],
            grad_inf: vec![0.1],
            reasons: vec!["gradtol"],
        };
        l.on_ask(&q);
        l.reeval_flags();
        l.reeval_flags();
        assert!(l.active_flags().contains(&FLAG_EI_COLLAPSED));
        // Stall: STALL_TRIALS tells with no improvement after the first.
        l.on_tell(1.0);
        for _ in 0..STALL_TRIALS {
            l.on_tell(2.0);
        }
        l.reeval_flags();
        l.reeval_flags();
        assert!(l.active_flags().contains(&FLAG_STALLED));
    }

    #[test]
    fn qn_window_flags_linesearch_pathology() {
        let mut l = HealthLedger::new();
        let q = AskQuality {
            trial_id: 0,
            log_ei: -1.0,
            iters: vec![7; MIN_QN_WINDOW],
            evals: vec![9; MIN_QN_WINDOW],
            grad_inf: vec![0.5; MIN_QN_WINDOW],
            reasons: vec!["linesearch"; MIN_QN_WINDOW],
        };
        l.on_ask(&q);
        l.reeval_flags();
        l.reeval_flags();
        assert!(l.active_flags().contains(&FLAG_QN_LINESEARCH_FAILING));
        let s = l.qn_summary().unwrap();
        assert_eq!(s.window, MIN_QN_WINDOW);
        assert_eq!(s.median_iters, 7.0);
        assert_eq!(s.converged_frac, 0.0);
        let ls = s.reasons.iter().find(|(t, _)| *t == "linesearch").unwrap();
        assert_eq!(ls.1, MIN_QN_WINDOW as u64);
    }

    #[test]
    fn loo_summary_coverage_and_lpd() {
        // Perfectly-calibrated unit residuals: z = 1 everywhere.
        let diag = LooDiagnostics {
            residuals: vec![1.0; 20],
            variances: vec![1.0; 20],
        };
        let s = LooSummary::from_diagnostics(&diag, 1.0).unwrap();
        assert_eq!(s.n, 20);
        assert_eq!(s.coverage95, 1.0);
        assert!((s.max_abs_z - 1.0).abs() < 1e-12);
        assert!((s.lpd - log_normal_pdf(1.0)).abs() < 1e-12);
        // Raw-units shift: lpd drops by ln σ_raw.
        let s2 = LooSummary::from_diagnostics(&diag, std::f64::consts::E).unwrap();
        assert!((s2.lpd - (s.lpd - 1.0)).abs() < 1e-12);
        // A 10σ outlier breaks coverage and max|z|.
        let diag = LooDiagnostics {
            residuals: vec![10.0; 1],
            variances: vec![1.0; 1],
        };
        let s = LooSummary::from_diagnostics(&diag, 1.0).unwrap();
        assert_eq!(s.coverage95, 0.0);
        assert!((s.max_abs_z - 10.0).abs() < 1e-12);
    }

    #[test]
    fn params_at_bound_detects_pinned_hyperparameters() {
        let inside =
            GpParams { log_len: 0.0, log_sf2: 0.0, log_noise: (1e-3f64).ln() };
        assert!(!params_at_bound(&inside, 1e-6));
        let pinned =
            GpParams { log_len: 0.0, log_sf2: 0.0, log_noise: (1e-6f64).ln() };
        assert!(params_at_bound(&pinned, 1e-6));
    }

    #[test]
    fn gauges_round_trip_absent_and_present() {
        let g = HealthGauges::new();
        assert_eq!(g.loo_lpd(), None);
        assert_eq!(g.best(), None);
        let mut l = HealthLedger::new();
        l.on_tell(2.5);
        l.observe_model(
            false,
            Some(LooSummary { n: 12, lpd: -1.25, max_abs_z: 2.0, coverage95: 0.9 }),
            12,
        );
        g.publish(&l);
        assert_eq!(g.best(), Some(2.5));
        assert_eq!(g.loo_lpd(), Some(-1.25));
        assert_eq!(g.stall(), 0);
    }
}
