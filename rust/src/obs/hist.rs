//! Lock-free power-of-two histograms (extracted from `hub/serve.rs`).
//!
//! Bucket `i` counts samples in `[2^i, 2^(i+1))` — 64 fixed buckets
//! cover every `u64` nanosecond value, recording is one relaxed
//! `fetch_add`, and the memory footprint is constant. One [`Hist`]
//! instance backs each latency site: serve request handling, pool
//! coalescing waits, journal fsyncs (see [`super::registry`]).
//!
//! Quantile reads **interpolate within the bucket** by rank: the
//! returned value walks linearly from the bucket's lower edge to its
//! upper edge as the target rank moves through the bucket's samples.
//! (The pre-extraction histogram reported a fixed bucket midpoint,
//! which pinned every quantile that landed in one bucket to the same
//! value and could sit a full 2× off a bucket-edge population.)

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// A 64-bucket power-of-two histogram over `u64` samples
/// (conventionally nanoseconds).
#[derive(Debug)]
pub struct Hist {
    buckets: [AtomicU64; 64],
}

impl Default for Hist {
    fn default() -> Self {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    /// Record a duration in nanoseconds (saturating at `u64::MAX`).
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Record a raw sample. Zero is clamped to 1 so it lands in the
    /// lowest bucket instead of shifting by 64.
    pub fn record_ns(&self, ns: u64) {
        let ns = ns.max(1);
        let idx = 63 - ns.leading_zeros() as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Approximate `q`-quantile (`0.0 ..= 1.0`), rank-interpolated
    /// within the bucket. Returns 0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        let counts: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64 * q).ceil() as u64).clamp(1, total);
        let mut before = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if before + c >= target {
                let lo = 1u64 << i;
                let width = lo; // bucket i spans [2^i, 2^(i+1))
                // Rank-interpolate: the j-th of the bucket's c samples
                // (1-based) sits at lo + width·(j − ½)/c, so a lone
                // sample reads the midpoint and a full sweep of ranks
                // walks the bucket edge to edge.
                let frac = (target - before) as f64 - 0.5;
                // `as u64` saturates, which also guards the top bucket
                // (lo = 2^63) against overflow.
                return (lo as f64 + width as f64 * frac / c as f64) as u64;
            }
            before += c;
        }
        unreachable!("cumulative count reaches total")
    }

    /// Fold another histogram into this one (bucket-wise sum). Both
    /// sides may be recording concurrently — each bucket is read and
    /// added relaxed, so the merge is a consistent-enough snapshot for
    /// exposition (the same guarantee a single `count()` read has).
    /// Merging is exact for quantiles: the merged histogram answers
    /// exactly as one that had recorded both sample streams.
    pub fn merge(&self, other: &Hist) {
        for (b, o) in self.buckets.iter().zip(&other.buckets) {
            let v = o.load(Ordering::Relaxed);
            if v > 0 {
                b.fetch_add(v, Ordering::Relaxed);
            }
        }
    }

    /// Non-empty buckets as `(upper_bound_exclusive, count)` pairs in
    /// ascending order — the raw material for Prometheus-style
    /// cumulative `le` buckets.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let c = b.load(Ordering::Relaxed);
                (c > 0).then(|| ((1u64 << i).saturating_mul(2), c))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_reads_zero() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 0, "q={q}");
        }
        assert!(h.nonzero_buckets().is_empty());
    }

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        let h = Hist::new();
        // 1023 and 1024 straddle the bucket-9/bucket-10 edge; 2047 is
        // the last value of bucket 10.
        h.record_ns(1023);
        assert_eq!(h.nonzero_buckets(), vec![(1024, 1)]);
        h.record_ns(1024);
        h.record_ns(2047);
        assert_eq!(h.nonzero_buckets(), vec![(1024, 1), (2048, 2)]);
        // Zero clamps into the lowest bucket instead of vanishing.
        h.record_ns(0);
        assert_eq!(h.nonzero_buckets()[0], (2, 1));
        assert_eq!(h.count(), 4);
    }

    #[test]
    fn quantiles_interpolate_within_the_bucket() {
        let h = Hist::new();
        // 100 samples, all in bucket [1024, 2048).
        for _ in 0..100 {
            h.record_ns(1500);
        }
        let p10 = h.quantile(0.10);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        // Interpolation must spread ranks across the bucket, not pin
        // them all to one midpoint/upper-bound value.
        assert!(p10 < p50 && p50 < p99, "p10={p10} p50={p50} p99={p99}");
        assert!((1024..2048).contains(&p10), "p10 stays in-bucket, got {p10}");
        assert!((1024..2048).contains(&p99), "p99 stays in-bucket, got {p99}");
        // p50 of a uniform bucket sits near the bucket middle.
        assert!((1400..=1700).contains(&p50), "p50 ≈ bucket middle, got {p50}");
    }

    #[test]
    fn p50_p99_on_a_known_bimodal_distribution() {
        let h = Hist::new();
        // 99 fast (~1.1 µs) + 1 slow (~1 ms): p50 fast, p100 slow.
        for _ in 0..99 {
            h.record(Duration::from_nanos(1_100));
        }
        h.record(Duration::from_millis(1));
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        let p100 = h.quantile(1.0);
        assert!((1024..2048).contains(&p50), "p50 in the fast bucket, got {p50}");
        assert!((1024..2048).contains(&p99), "p99 still fast (rank 99), got {p99}");
        assert!(
            (524_288..=1_048_576).contains(&p100),
            "max in the ~1 ms bucket, got {p100}"
        );
    }

    #[test]
    fn a_single_sample_reads_its_bucket_midpoint() {
        let h = Hist::new();
        h.record_ns(1_000_000); // bucket [2^19, 2^20)
        let mid = (1u64 << 19) + (1u64 << 18);
        for q in [0.01, 0.5, 1.0] {
            assert_eq!(h.quantile(q), mid, "q={q}");
        }
    }

    /// `merge` must be exact: the merged histogram answers every
    /// quantile exactly as a single histogram that recorded both
    /// sample streams (bucket-wise sums commute with rank walks).
    #[test]
    fn merged_histogram_matches_combined_recording() {
        let a = Hist::new();
        let b = Hist::new();
        let combined = Hist::new();
        // Two very different shapes: a tight fast mode and a heavy tail.
        for i in 0..200u64 {
            let fast = 1_000 + i * 7;
            a.record_ns(fast);
            combined.record_ns(fast);
        }
        for i in 0..50u64 {
            let slow = 1_000_000 + i * 100_000;
            b.record_ns(slow);
            combined.record_ns(slow);
        }
        a.merge(&b);
        assert_eq!(a.count(), combined.count());
        assert_eq!(a.nonzero_buckets(), combined.nonzero_buckets());
        for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            assert_eq!(a.quantile(q), combined.quantile(q), "q={q}");
        }
        // Merging an empty histogram is a no-op.
        let before = a.nonzero_buckets();
        a.merge(&Hist::new());
        assert_eq!(a.nonzero_buckets(), before);
    }

    #[test]
    fn top_bucket_saturates_without_overflow() {
        let h = Hist::new();
        h.record_ns(u64::MAX);
        h.record(Duration::from_secs(u64::MAX)); // as_nanos > u64::MAX
        let q = h.quantile(1.0);
        assert!(q >= 1u64 << 63, "top bucket lower edge, got {q}");
        assert_eq!(h.count(), 2);
        // The exclusive upper bound saturates instead of wrapping.
        assert_eq!(h.nonzero_buckets(), vec![(u64::MAX, 2)]);
    }
}
