//! Crate-wide error type (std-only; no `thiserror`/`eyre` offline).

use std::fmt;

/// Unified error for all dbe-bo layers.
#[derive(Debug)]
pub enum Error {
    /// Linear-algebra failure (e.g. Cholesky of a non-PD matrix).
    Linalg(String),
    /// Optimizer failure (line search, invalid bounds, NaN objective).
    Optim(String),
    /// GP model failure (degenerate data, fit divergence).
    Gp(String),
    /// PJRT runtime failure (artifact missing, compile/execute error).
    Runtime(String),
    /// Configuration / CLI error.
    Config(String),
    /// Coordinator/channel failure.
    Coordinator(String),
    /// Study-hub failure (unknown study/trial, journal corruption,
    /// replay mismatch).
    Hub(String),
    /// Backpressure: a bounded per-study mailbox is at capacity. The
    /// request was **not** enqueued; callers should retry later. The
    /// serving tier maps this to the wire-level `busy` error frame.
    Busy(String),
    /// A study actor panicked and exhausted its restart budget (or
    /// could not be rebuilt from its journal). Terminal for that
    /// study: every further request answers with this. The serving
    /// tier maps it to the wire-level `crashed` frame.
    Crashed(String),
    /// A study actor panicked and was restarted by replaying its
    /// journal segment. The in-flight request was **not** applied
    /// beyond what the journal recorded; callers should snapshot to
    /// resync pending trials, then retry. Maps to the wire-level
    /// `restarting` frame.
    Restarting(String),
    /// I/O error.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Linalg(m) => write!(f, "linalg error: {m}"),
            Error::Optim(m) => write!(f, "optimizer error: {m}"),
            Error::Gp(m) => write!(f, "gp error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Hub(m) => write!(f, "hub error: {m}"),
            Error::Busy(m) => write!(f, "busy: {m}"),
            Error::Crashed(m) => write!(f, "crashed: {m}"),
            Error::Restarting(m) => write!(f, "restarting: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        assert!(Error::Linalg("x".into()).to_string().contains("linalg"));
        assert!(Error::Optim("x".into()).to_string().contains("optimizer"));
        assert!(Error::Gp("x".into()).to_string().contains("gp"));
        assert!(Error::Runtime("x".into()).to_string().contains("runtime"));
        assert!(Error::Config("x".into()).to_string().contains("config"));
        assert!(Error::Coordinator("x".into()).to_string().contains("coordinator"));
        assert!(Error::Hub("x".into()).to_string().contains("hub"));
        assert!(Error::Busy("x".into()).to_string().contains("busy"));
        assert!(Error::Crashed("x".into()).to_string().contains("crashed"));
        assert!(Error::Restarting("x".into()).to_string().contains("restarting"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "missing");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("missing"));
    }
}
