//! Thread-channel coordination layer.
//!
//! The paper's coroutine decouples optimizer states *within* one MSO
//! call; this module scales the same idea *across* concurrent BO
//! studies and OS threads, vLLM-router-style:
//!
//! * [`service::BatchService`] — a worker thread owning a
//!   [`crate::batcheval::BatchAcqEvaluator`]; clients submit evaluation
//!   requests over an mpsc channel and the service **coalesces** queued
//!   requests into one oracle batch (size- and deadline-triggered
//!   microbatching). The handle is `Send + Sync`, so the shard workers
//!   of a [`ParDbe`](crate::optim::mso::ParDbe) run can share one handle
//!   by reference — their per-shard submissions merge into large oracle
//!   batches even though shards advance asynchronously.
//! * [`router::Router`] — routes requests across several services
//!   (least-loaded pick) for multi-worker deployments.
//! * [`metrics::Metrics`] — atomic counters surfaced by the CLI; the
//!   [`metrics::ShardedMetrics`] registry gives every Par-D-BE shard its
//!   own counter set.
//!
//! The study hub's shared acquisition pool
//! ([`crate::hub::pool::AcqPool`]) is the multi-tenant generalization
//! of [`service::BatchService`]: same drain/coalesce discipline and the
//! same [`metrics::Metrics`] counting rules, with per-submission
//! evaluator keys so many studies' differing GPs can share one worker
//! pool.
//!
//! All of it is std-only (`std::thread` + `std::sync::mpsc`): tokio is
//! unavailable offline, and the workload — few long-lived workers, small
//! message rate — is exactly what blocking channels are good at.

pub mod metrics;
pub mod router;
pub mod service;

pub use metrics::{Metrics, MetricsSnapshot, ShardedMetrics};
pub use router::Router;
pub use service::{BatchService, ServiceConfig};
