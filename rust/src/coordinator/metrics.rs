//! Atomic metrics registry for the coordinator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Counters shared by services/routers. All methods are lock-free.
///
/// Counting discipline (shared with
/// [`CountingEvaluator`](crate::batcheval::CountingEvaluator)):
/// `batches`/`points`/`oracle_nanos` count **successful** oracle calls
/// only; failed dispatches increment `failures` instead. A concurrent
/// [`snapshot`](Metrics::snapshot) may observe a batch whose sibling
/// counters have not landed yet (the three adds are not one atomic
/// transaction); totals are exact once submitters have quiesced.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Evaluation requests accepted.
    pub requests: AtomicU64,
    /// Oracle batches dispatched successfully.
    pub batches: AtomicU64,
    /// Total points evaluated successfully.
    pub points: AtomicU64,
    /// Cumulative oracle wall time in nanoseconds.
    pub oracle_nanos: AtomicU64,
    /// Requests that failed.
    pub failures: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, points: usize, wall: Duration) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.points.fetch_add(points as u64, Ordering::Relaxed);
        self.oracle_nanos.fetch_add(wall.as_nanos() as u64, Ordering::Relaxed);
        // Mirror into the unified registry: instances come and go (one
        // Metrics per service/pool), the process-wide totals persist.
        crate::obs::registry::counter("coordinator.batches").inc();
        crate::obs::registry::counter("coordinator.points").add(points as u64);
        crate::obs::registry::hist("coordinator.oracle_ns").record(wall);
    }

    /// Mean points per oracle batch — the batching-efficiency headline.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.points.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            points: self.points.load(Ordering::Relaxed),
            oracle: Duration::from_nanos(self.oracle_nanos.load(Ordering::Relaxed)),
            failures: self.failures.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of the counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub points: u64,
    pub oracle: Duration,
    pub failures: u64,
}

/// Fixed-size registry of per-shard [`Metrics`].
///
/// Used by [`ParDbe`](crate::optim::mso::ParDbe) to account each
/// worker's evaluator submissions separately: `shard(i)` hands shard
/// `i`'s counters to its worker thread (all methods are `&self` and
/// lock-free, so the registry is shared by reference across a thread
/// scope), and [`aggregate`](ShardedMetrics::aggregate) folds them into
/// one whole-run snapshot.
#[derive(Debug)]
pub struct ShardedMetrics {
    shards: Vec<Metrics>,
}

impl ShardedMetrics {
    pub fn new(n_shards: usize) -> Self {
        ShardedMetrics { shards: (0..n_shards).map(|_| Metrics::new()).collect() }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Counters of one shard (panics if `i` is out of range).
    pub fn shard(&self, i: usize) -> &Metrics {
        &self.shards[i]
    }

    /// Sum of all shard counters.
    pub fn aggregate(&self) -> MetricsSnapshot {
        let mut total = MetricsSnapshot {
            requests: 0,
            batches: 0,
            points: 0,
            oracle: Duration::ZERO,
            failures: 0,
        };
        for m in &self.shards {
            let s = m.snapshot();
            total.requests += s.requests;
            total.batches += s.batches;
            total.points += s.points;
            total.oracle += s.oracle;
            total.failures += s.failures;
        }
        total
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} batches={} points={} oracle={:.1}ms failures={}",
            self.requests,
            self.batches,
            self.points,
            self.oracle.as_secs_f64() * 1e3,
            self.failures
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(10, Duration::from_millis(2));
        m.record_batch(6, Duration::from_millis(1));
        assert_eq!(m.snapshot().batches, 2);
        assert_eq!(m.snapshot().points, 16);
        assert!((m.mean_batch_size() - 8.0).abs() < 1e-12);
        assert_eq!(m.snapshot().oracle, Duration::from_millis(3));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.mean_batch_size(), 0.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert!(format!("{s}").contains("batches=0"));
    }

    #[test]
    fn sharded_aggregate_sums_shards() {
        let sm = ShardedMetrics::new(3);
        sm.shard(0).record_batch(4, Duration::from_millis(1));
        sm.shard(1).record_batch(2, Duration::from_millis(2));
        sm.shard(1).record_batch(1, Duration::from_millis(1));
        let agg = sm.aggregate();
        assert_eq!(agg.batches, 3);
        assert_eq!(agg.points, 7);
        assert_eq!(agg.oracle, Duration::from_millis(4));
        assert_eq!(sm.shard(2).snapshot().batches, 0);
        assert_eq!(sm.n_shards(), 3);
    }

    #[test]
    fn sharded_metrics_concurrent_recording_is_exact() {
        // Each worker thread hammers its own shard; totals must be
        // exact (no lost updates) once the threads have joined.
        let sm = std::sync::Arc::new(ShardedMetrics::new(4));
        let mut joins = Vec::new();
        for s in 0..4 {
            let sm = std::sync::Arc::clone(&sm);
            joins.push(std::thread::spawn(move || {
                for _ in 0..500 {
                    sm.shard(s).record_batch(3, Duration::from_nanos(10));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let agg = sm.aggregate();
        assert_eq!(agg.batches, 4 * 500);
        assert_eq!(agg.points, 4 * 500 * 3);
    }
}
