//! Atomic metrics registry for the coordinator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Counters shared by services/routers. All methods are lock-free.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Evaluation requests accepted.
    pub requests: AtomicU64,
    /// Oracle batches dispatched.
    pub batches: AtomicU64,
    /// Total points evaluated.
    pub points: AtomicU64,
    /// Cumulative oracle wall time in nanoseconds.
    pub oracle_nanos: AtomicU64,
    /// Requests that failed.
    pub failures: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, points: usize, wall: Duration) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.points.fetch_add(points as u64, Ordering::Relaxed);
        self.oracle_nanos.fetch_add(wall.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Mean points per oracle batch — the batching-efficiency headline.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.points.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            points: self.points.load(Ordering::Relaxed),
            oracle: Duration::from_nanos(self.oracle_nanos.load(Ordering::Relaxed)),
            failures: self.failures.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of the counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub points: u64,
    pub oracle: Duration,
    pub failures: u64,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} batches={} points={} oracle={:.1}ms failures={}",
            self.requests,
            self.batches,
            self.points,
            self.oracle.as_secs_f64() * 1e3,
            self.failures
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(10, Duration::from_millis(2));
        m.record_batch(6, Duration::from_millis(1));
        assert_eq!(m.snapshot().batches, 2);
        assert_eq!(m.snapshot().points, 16);
        assert!((m.mean_batch_size() - 8.0).abs() < 1e-12);
        assert_eq!(m.snapshot().oracle, Duration::from_millis(3));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.mean_batch_size(), 0.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert!(format!("{s}").contains("batches=0"));
    }
}
