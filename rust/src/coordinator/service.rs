//! Batch-coalescing evaluation service.
//!
//! One worker thread owns the evaluator. Clients (e.g. concurrent BO
//! studies, or the shard workers of a
//! [`ParDbe`](crate::optim::mso::ParDbe) run) send `(points, reply)`
//! requests; the worker drains everything queued (up to `max_batch`
//! points, waiting at most `max_wait` after the first request) and
//! dispatches ONE oracle call for the coalesced batch — the same
//! microbatching discipline a vLLM-style router uses, applied to
//! acquisition evaluations.
//!
//! The [`BatchService`] handle is `Send + Sync` (the sender sits behind
//! a short-lived mutex), so one handle can be shared by reference across
//! a thread scope — the shape Par-D-BE needs. Cloning the handle per
//! thread also works and avoids even that brief lock.
//!
//! Shutdown discipline: the worker exits when every handle is dropped
//! AND the request queue is empty — `mpsc` receivers keep yielding
//! queued messages after all senders disconnect, so in-flight requests
//! are drained and answered, never dropped.

use super::metrics::Metrics;
use crate::batcheval::BatchAcqEvaluator;
use crate::error::{Error, Result};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Coalesce at most this many points into one oracle call.
    pub max_batch: usize,
    /// After the first queued request, wait at most this long for more.
    pub max_wait: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { max_batch: 64, max_wait: Duration::from_micros(200) }
    }
}

struct Request {
    points: Vec<Vec<f64>>,
    reply: Sender<Result<(Vec<f64>, Vec<Vec<f64>>)>>,
}

/// Handle to a running batch service. Cloning shares the same worker.
///
/// The handle is `Send + Sync`: `mpsc::Sender` alone does not guarantee
/// `Sync` across toolchain versions, so the sender lives behind a mutex
/// held only for the (non-blocking) enqueue.
pub struct BatchService {
    tx: Mutex<Sender<Request>>,
    pub metrics: Arc<Metrics>,
    dim: usize,
}

// Compile-time guarantee that a handle can be shared by reference
// across Par-D-BE shard threads.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<BatchService>();
};

impl Clone for BatchService {
    fn clone(&self) -> Self {
        BatchService {
            tx: Mutex::new(self.lock_tx().clone()),
            metrics: Arc::clone(&self.metrics),
            dim: self.dim,
        }
    }
}

impl BatchService {
    /// Spawn the worker thread owning `evaluator`.
    pub fn spawn(
        evaluator: Box<dyn BatchAcqEvaluator + Send>,
        cfg: ServiceConfig,
    ) -> (Self, JoinHandle<()>) {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::new());
        let m = Arc::clone(&metrics);
        let dim = evaluator.dim();
        let handle = std::thread::spawn(move || worker_loop(evaluator, cfg, rx, m));
        (BatchService { tx: Mutex::new(tx), metrics, dim }, handle)
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    fn lock_tx(&self) -> std::sync::MutexGuard<'_, Sender<Request>> {
        // A panic between lock and unlock cannot leave the sender in a
        // bad state (send is atomic), so poisoning is ignored.
        self.tx.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Evaluate a batch through the service (blocking).
    pub fn eval(&self, points: Vec<Vec<f64>>) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
        self.metrics.requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (reply_tx, reply_rx) = channel();
        self.lock_tx()
            .send(Request { points, reply: reply_tx })
            .map_err(|_| Error::Coordinator("service worker is gone".into()))?;
        reply_rx
            .recv()
            .map_err(|_| Error::Coordinator("service dropped the reply".into()))?
    }
}

/// A [`BatchAcqEvaluator`] view of the service, so MSO strategies can
/// run against a shared coalescing worker transparently.
impl BatchAcqEvaluator for BatchService {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval_batch(&self, xs: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
        self.eval(xs.to_vec())
    }

    fn name(&self) -> &str {
        "batch-service"
    }
}

fn worker_loop(
    evaluator: Box<dyn BatchAcqEvaluator + Send>,
    cfg: ServiceConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
) {
    loop {
        // Block for the first request; exit when all senders are gone.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return,
        };
        let mut pending = vec![first];
        let mut total_points = pending[0].points.len();
        let deadline = Instant::now() + cfg.max_wait;

        // Coalesce whatever arrives before the deadline / size cap.
        while total_points < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => {
                    total_points += r.points.len();
                    pending.push(r);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // One oracle call for the whole coalesced batch. Only successful
        // calls land in batches/points (see the [`Metrics`] counting
        // discipline); failures count separately.
        let all_points: Vec<Vec<f64>> =
            pending.iter().flat_map(|r| r.points.iter().cloned()).collect();
        let t0 = Instant::now();
        let outcome = evaluator.eval_batch(&all_points);

        match outcome {
            Ok((vals, grads)) => {
                metrics.record_batch(all_points.len(), t0.elapsed());
                let mut off = 0;
                for req in pending {
                    let k = req.points.len();
                    let chunk = (
                        vals[off..off + k].to_vec(),
                        grads[off..off + k].to_vec(),
                    );
                    off += k;
                    let _ = req.reply.send(Ok(chunk)); // receiver may be gone
                }
            }
            Err(e) => {
                metrics.failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let msg = e.to_string();
                for req in pending {
                    let _ = req.reply.send(Err(Error::Coordinator(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batcheval::SyntheticEvaluator;
    use crate::bbob::{Objective, Rosenbrock};
    use crate::testing::forall;

    fn spawn_rosen(d: usize, cfg: ServiceConfig) -> (BatchService, JoinHandle<()>) {
        BatchService::spawn(Box::new(SyntheticEvaluator::new(Box::new(Rosenbrock::new(d)))), cfg)
    }

    #[test]
    fn answers_match_direct_evaluation() {
        let (svc, handle) = spawn_rosen(3, ServiceConfig::default());
        let f = Rosenbrock::new(3);
        let pts = vec![vec![0.5; 3], vec![2.0, 1.0, 0.1]];
        let (vals, grads) = svc.eval(pts.clone()).unwrap();
        for (i, p) in pts.iter().enumerate() {
            let (v, g) = f.value_grad(p);
            assert_eq!(vals[i], v);
            assert_eq!(grads[i], g);
        }
        drop(svc);
        handle.join().unwrap();
    }

    #[test]
    fn concurrent_clients_get_their_own_answers() {
        // The routing invariant: coalescing must never cross-wire
        // replies. Hammer the service from many threads and check every
        // reply against the direct oracle.
        let (svc, handle) =
            spawn_rosen(2, ServiceConfig { max_batch: 16, max_wait: Duration::from_millis(1) });
        let mut joins = Vec::new();
        for t in 0..8 {
            let svc = svc.clone();
            joins.push(std::thread::spawn(move || {
                let f = Rosenbrock::new(2);
                for i in 0..50 {
                    let p = vec![0.1 + 0.01 * t as f64, 0.2 + 0.01 * i as f64];
                    let (vals, grads) = svc.eval(vec![p.clone()]).unwrap();
                    let (v, g) = f.value_grad(&p);
                    assert_eq!(vals[0], v, "client {t} iteration {i} got wrong value");
                    assert_eq!(grads[0], g);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        // Coalescing must have actually happened at least sometimes
        // (400 requests; some land in shared batches).
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.points, 400);
        assert!(snap.batches <= snap.points, "{snap}");
        drop(svc);
        handle.join().unwrap();
    }

    #[test]
    fn batch_size_cap_respected() {
        let (svc, handle) =
            spawn_rosen(2, ServiceConfig { max_batch: 4, max_wait: Duration::from_millis(5) });
        // One request with 10 points still evaluates all 10 (cap only
        // limits *coalescing*, not correctness).
        let pts: Vec<Vec<f64>> = (0..10).map(|i| vec![0.1 * i as f64, 0.5]).collect();
        let (vals, _) = svc.eval(pts).unwrap();
        assert_eq!(vals.len(), 10);
        drop(svc);
        handle.join().unwrap();
    }

    #[test]
    fn property_batch_reply_partition() {
        // Property: for any request-size pattern, each client's reply
        // has exactly its own length and matches the oracle.
        forall("service reply partition", 10, |g| {
            let (svc, handle) = spawn_rosen(
                2,
                ServiceConfig { max_batch: g.size(16), max_wait: Duration::from_micros(300) },
            );
            let f = Rosenbrock::new(2);
            let n_clients = g.size(5);
            let mut joins = Vec::new();
            for c in 0..n_clients {
                let svc = svc.clone();
                let k = 1 + (c % 3);
                joins.push(std::thread::spawn(move || -> std::result::Result<(), String> {
                    let pts: Vec<Vec<f64>> =
                        (0..k).map(|i| vec![0.3 + 0.1 * c as f64, 0.2 + 0.1 * i as f64]).collect();
                    let f = Rosenbrock::new(2);
                    let (vals, _) = svc.eval(pts.clone()).map_err(|e| e.to_string())?;
                    if vals.len() != k {
                        return Err(format!("client {c}: got {} values, want {k}", vals.len()));
                    }
                    for (i, p) in pts.iter().enumerate() {
                        if vals[i] != f.value(p) {
                            return Err(format!("client {c}: wrong value at {i}"));
                        }
                    }
                    Ok(())
                }));
            }
            let _ = &f;
            for j in joins {
                j.join().map_err(|_| "client panicked".to_string())??;
            }
            drop(svc);
            handle.join().map_err(|_| "worker panicked".to_string())?;
            Ok(())
        });
    }

    #[test]
    fn mso_runs_through_service() {
        use crate::optim::lbfgsb::LbfgsbOptions;
        use crate::optim::mso::{run_mso, MsoConfig, MsoStrategy};
        let (svc, handle) = spawn_rosen(3, ServiceConfig::default());
        let cfg = MsoConfig { bounds: vec![(0.0, 3.0); 3], lbfgsb: LbfgsbOptions::default() };
        let x0s = vec![vec![2.0; 3], vec![0.5; 3]];
        let res = run_mso(MsoStrategy::Dbe, &svc, &x0s, &cfg).unwrap();
        assert!(res.best_f < 1e-6);
        drop(svc);
        handle.join().unwrap();
    }

    #[test]
    fn one_handle_shared_by_reference_across_par_dbe_shards() {
        // The Sync-handle path: Par-D-BE shard threads share ONE
        // BatchService by reference (no per-thread clones), and the
        // worker coalesces their submissions.
        use crate::optim::lbfgsb::LbfgsbOptions;
        use crate::optim::mso::{MsoConfig, ParDbe};
        let (svc, handle) = spawn_rosen(
            3,
            ServiceConfig { max_batch: 32, max_wait: Duration::from_micros(300) },
        );
        let cfg = MsoConfig { bounds: vec![(0.0, 3.0); 3], lbfgsb: LbfgsbOptions::default() };
        let x0s = vec![vec![2.0; 3], vec![0.5; 3], vec![1.5; 3], vec![2.8; 3]];
        let res = ParDbe::with_workers(2).run(&svc, &x0s, &cfg).unwrap();
        assert!(res.best_f < 1e-6);
        assert_eq!(res.shards.len(), 2);
        let snap = svc.metrics.snapshot();
        // Client-side submissions ≥ worker-side oracle batches means
        // coalescing merged at least some cross-shard submissions (and
        // never lost one).
        assert_eq!(snap.points as usize, res.n_points);
        assert!(snap.batches as usize <= res.n_batches);
        drop(svc);
        handle.join().unwrap();
    }

    #[test]
    fn failed_oracle_counts_failures_not_batches() {
        struct AlwaysFails;
        impl BatchAcqEvaluator for AlwaysFails {
            fn dim(&self) -> usize {
                2
            }
            fn eval_batch(&self, _: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
                Err(Error::Runtime("oracle down".into()))
            }
        }
        let (svc, handle) = BatchService::spawn(Box::new(AlwaysFails), ServiceConfig::default());
        assert!(svc.eval(vec![vec![0.0; 2]]).is_err());
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.failures, 1);
        assert_eq!(snap.batches, 0, "failed dispatches must not count as batches");
        assert_eq!(snap.points, 0, "failed dispatches must not count points");
        assert_eq!(snap.requests, 1);
        drop(svc);
        handle.join().unwrap();
    }
}
