//! Least-loaded routing across multiple batch services.

use super::service::BatchService;
use crate::batcheval::BatchAcqEvaluator;
use crate::error::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Routes evaluation batches across workers, picking the one with the
/// fewest in-flight points (ties broken round-robin).
pub struct Router {
    workers: Vec<BatchService>,
    inflight: Vec<Arc<AtomicU64>>,
    rr: AtomicU64,
}

impl Router {
    pub fn new(workers: Vec<BatchService>) -> Result<Self> {
        if workers.is_empty() {
            return Err(Error::Coordinator("router needs at least one worker".into()));
        }
        let dim = workers[0].dim();
        if workers.iter().any(|w| w.dim() != dim) {
            return Err(Error::Coordinator("router workers disagree on dimension".into()));
        }
        let inflight = workers.iter().map(|_| Arc::new(AtomicU64::new(0))).collect();
        Ok(Router { workers, inflight, rr: AtomicU64::new(0) })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    fn pick(&self) -> usize {
        let rr = self.rr.fetch_add(1, Ordering::Relaxed) as usize;
        let mut best = rr % self.workers.len();
        let mut best_load = self.inflight[best].load(Ordering::Relaxed);
        for k in 0..self.workers.len() {
            let i = (rr + k) % self.workers.len();
            let load = self.inflight[i].load(Ordering::Relaxed);
            if load < best_load {
                best = i;
                best_load = load;
            }
        }
        best
    }

    /// Total points routed to each worker so far (diagnostics).
    pub fn worker_points(&self) -> Vec<u64> {
        self.workers.iter().map(|w| w.metrics.snapshot().points).collect()
    }
}

impl BatchAcqEvaluator for Router {
    fn dim(&self) -> usize {
        self.workers[0].dim()
    }

    fn eval_batch(&self, xs: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
        let w = self.pick();
        self.inflight[w].fetch_add(xs.len() as u64, Ordering::Relaxed);
        let out = self.workers[w].eval(xs.to_vec());
        self.inflight[w].fetch_sub(xs.len() as u64, Ordering::Relaxed);
        out
    }

    fn name(&self) -> &str {
        "router"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batcheval::SyntheticEvaluator;
    use crate::bbob::{Objective, Rosenbrock};
    use crate::coordinator::service::ServiceConfig;

    fn make_router(n: usize) -> (Router, Vec<std::thread::JoinHandle<()>>) {
        let mut workers = Vec::new();
        let mut handles = Vec::new();
        for _ in 0..n {
            let (svc, h) = BatchService::spawn(
                Box::new(SyntheticEvaluator::new(Box::new(Rosenbrock::new(2)))),
                ServiceConfig::default(),
            );
            workers.push(svc);
            handles.push(h);
        }
        (Router::new(workers).unwrap(), handles)
    }

    #[test]
    fn routes_and_answers_correctly() {
        let (router, _handles) = make_router(3);
        let f = Rosenbrock::new(2);
        for i in 0..30 {
            let p = vec![0.1 * (i % 10) as f64, 1.0];
            let (vals, _) = router.eval_batch(std::slice::from_ref(&p)).unwrap();
            assert_eq!(vals[0], f.value(&p));
        }
        // Work must be spread across workers.
        let loads = router.worker_points();
        assert_eq!(loads.iter().sum::<u64>(), 30);
        assert!(loads.iter().filter(|&&l| l > 0).count() >= 2, "{loads:?}");
    }

    #[test]
    fn rejects_empty_and_mismatched() {
        assert!(Router::new(Vec::new()).is_err());
        let (svc2, _h2) = BatchService::spawn(
            Box::new(SyntheticEvaluator::new(Box::new(Rosenbrock::new(2)))),
            ServiceConfig::default(),
        );
        let (svc3, _h3) = BatchService::spawn(
            Box::new(SyntheticEvaluator::new(Box::new(Rosenbrock::new(3)))),
            ServiceConfig::default(),
        );
        assert!(Router::new(vec![svc2, svc3]).is_err());
    }
}
