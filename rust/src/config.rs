//! Experiment configuration shared by the repro harness, benches, and
//! examples — one struct capturing the paper's protocol with CLI
//! overrides.

use crate::cli::Args;
use crate::optim::lbfgsb::LbfgsbOptions;
use crate::optim::mso::MsoStrategy;
use crate::Result;

/// The paper's benchmark protocol (§5) with scaling knobs.
#[derive(Clone, Debug)]
pub struct BenchProtocol {
    /// Objectives by name.
    pub objectives: Vec<String>,
    /// Dimensions swept.
    pub dims: Vec<usize>,
    /// BO trials per study (paper: 300).
    pub trials: usize,
    /// Independent seeds per cell (paper: 20).
    pub seeds: usize,
    /// MSO restarts B (paper: 10).
    pub restarts: usize,
    /// Random startup trials.
    pub startup: usize,
    /// L-BFGS-B settings (paper: m=10, 200 iters, pgtol 1e-2).
    pub lbfgsb: LbfgsbOptions,
    /// Output directory for CSV dumps.
    pub out_dir: String,
    /// Also bench the sharded Par-D-BE strategy (beyond the paper's
    /// three; see [`MsoStrategy::ParDbe`]).
    pub with_par: bool,
    /// Worker threads for Par-D-BE (0 = one per core).
    pub par_workers: usize,
    /// Full GP hyperparameter refit every k trials; in between, new
    /// observations take the O(n²) incremental `refit_append` path
    /// (1 = refit every trial, the paper's protocol).
    pub fit_every: usize,
    /// Candidates per hub ask (constant-liar q-batch size; 1 = plain
    /// sequential ask/tell). Used by `dbe-bo hub` and the hub bench.
    pub q: usize,
    /// Worker threads of the hub's shared acquisition pool (0 = pool
    /// disabled, each study evaluates natively).
    pub hub_workers: usize,
    /// Closed-loop loopback clients for the serve-throughput bench.
    pub clients: usize,
}

impl Default for BenchProtocol {
    fn default() -> Self {
        BenchProtocol {
            objectives: vec![
                "sphere".into(),
                "attractive_sector".into(),
                "step_ellipsoidal".into(),
                "rastrigin".into(),
            ],
            dims: vec![5, 10, 20, 40],
            // Scaled-down defaults (see EXPERIMENTS.md §Scaling);
            // `--paper` restores the full protocol.
            trials: 60,
            seeds: 5,
            restarts: 10,
            startup: 10,
            lbfgsb: LbfgsbOptions {
                memory: 10,
                pgtol: 1e-2,
                ftol: 0.0,
                max_iters: 200,
                max_evals: 50_000,
            },
            out_dir: "results".into(),
            with_par: false,
            par_workers: 0,
            fit_every: 1,
            q: 1,
            hub_workers: 0,
            clients: 4,
        }
    }
}

impl BenchProtocol {
    /// Apply CLI overrides: `--trials`, `--seeds`, `--dims`,
    /// `--objectives`, `--restarts`, `--out`, `--fast`, `--paper`,
    /// `--with-par`, `--par-workers`, `--fit-every`, `--q`,
    /// `--hub-workers`, `--clients`.
    pub fn from_args(args: &Args) -> Result<Self> {
        let mut p = BenchProtocol::default();
        if args.has("paper") {
            p.trials = 300;
            p.seeds = 20;
        }
        if args.has("fast") {
            p.trials = 30;
            p.seeds = 2;
            p.dims = vec![5, 10];
        }
        p.trials = args.get_usize("trials", p.trials)?;
        p.seeds = args.get_usize("seeds", p.seeds)?;
        p.restarts = args.get_usize("restarts", p.restarts)?;
        p.dims = args.get_usize_list("dims", &p.dims)?;
        p.out_dir = args.get_str("out", &p.out_dir);
        p.with_par = p.with_par || args.has("with-par");
        p.par_workers = args.get_usize("par-workers", p.par_workers)?;
        p.fit_every = args.get_usize("fit-every", p.fit_every)?.max(1);
        p.q = args.get_usize("q", p.q)?.max(1);
        p.hub_workers = args.get_usize("hub-workers", p.hub_workers)?;
        p.clients = args.get_usize("clients", p.clients)?.max(1);
        if args.has("objectives") {
            p.objectives = args
                .get_str("objectives", "")
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().to_string())
                .collect();
        }
        Ok(p)
    }

    /// Strategies this protocol benches: the paper's three, plus
    /// Par-D-BE when `--with-par` is set.
    pub fn strategies(&self) -> Vec<MsoStrategy> {
        let mut s = MsoStrategy::all().to_vec();
        if self.with_par {
            s.push(MsoStrategy::ParDbe);
        }
        s
    }
}

/// Write a CSV file, creating the directory if needed.
pub fn write_csv(dir: &str, name: &str, header: &str, rows: &[String]) -> Result<String> {
    std::fs::create_dir_all(dir)?;
    let path = format!("{dir}/{name}");
    let mut body = String::with_capacity(rows.len() * 32 + header.len() + 1);
    body.push_str(header);
    body.push('\n');
    for r in rows {
        body.push_str(r);
        body.push('\n');
    }
    std::fs::write(&path, body)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_follow_paper_protocol_shape() {
        let p = BenchProtocol::default();
        assert_eq!(p.restarts, 10);
        assert_eq!(p.lbfgsb.memory, 10);
        assert_eq!(p.lbfgsb.max_iters, 200);
        assert!((p.lbfgsb.pgtol - 1e-2).abs() < 1e-15);
        assert_eq!(p.objectives.len(), 4);
        assert_eq!(p.dims, vec![5, 10, 20, 40]);
    }

    #[test]
    fn cli_overrides() {
        let args = crate::cli::Args::parse(
            ["--trials", "12", "--dims", "5", "--objectives", "rastrigin", "--fast"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let p = BenchProtocol::from_args(&args).unwrap();
        assert_eq!(p.trials, 12); // explicit beats --fast
        assert_eq!(p.dims, vec![5]);
        assert_eq!(p.objectives, vec!["rastrigin"]);
        assert_eq!(p.seeds, 2); // from --fast
    }

    #[test]
    fn par_strategy_selection() {
        let p = BenchProtocol::default();
        assert_eq!(p.strategies().len(), 3, "paper protocol by default");
        let args = crate::cli::Args::parse(
            ["--with-par", "--par-workers", "4"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let p = BenchProtocol::from_args(&args).unwrap();
        assert!(p.with_par);
        assert_eq!(p.par_workers, 4);
        assert_eq!(*p.strategies().last().unwrap(), MsoStrategy::ParDbe);
    }

    #[test]
    fn fit_every_override_with_floor() {
        let p = BenchProtocol::default();
        assert_eq!(p.fit_every, 1, "paper protocol refits every trial");
        let args = crate::cli::Args::parse(
            ["--fit-every", "4"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        assert_eq!(BenchProtocol::from_args(&args).unwrap().fit_every, 4);
        let args =
            crate::cli::Args::parse(["--fit-every", "0"].iter().map(|s| s.to_string()))
                .unwrap();
        assert_eq!(BenchProtocol::from_args(&args).unwrap().fit_every, 1);
    }

    #[test]
    fn hub_overrides_with_floors() {
        let p = BenchProtocol::default();
        assert_eq!(p.q, 1);
        assert_eq!(p.hub_workers, 0);
        let args = crate::cli::Args::parse(
            ["--q", "3", "--hub-workers", "2"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let p = BenchProtocol::from_args(&args).unwrap();
        assert_eq!(p.q, 3);
        assert_eq!(p.hub_workers, 2);
        let args =
            crate::cli::Args::parse(["--q", "0"].iter().map(|s| s.to_string())).unwrap();
        assert_eq!(BenchProtocol::from_args(&args).unwrap().q, 1, "q floors at 1");
    }

    #[test]
    fn paper_flag_restores_full_protocol() {
        let args =
            crate::cli::Args::parse(["--paper"].iter().map(|s| s.to_string())).unwrap();
        let p = BenchProtocol::from_args(&args).unwrap();
        assert_eq!(p.trials, 300);
        assert_eq!(p.seeds, 20);
    }

    #[test]
    fn csv_writer_writes() {
        let dir = std::env::temp_dir().join(format!("dbe_bo_csv_{}", std::process::id()));
        let path = write_csv(
            dir.to_str().unwrap(),
            "t.csv",
            "a,b",
            &["1,2".to_string(), "3,4".to_string()],
        )
        .unwrap();
        let body = std::fs::read_to_string(path).unwrap();
        assert_eq!(body, "a,b\n1,2\n3,4\n");
    }
}
