//! Chaos battery for the crash-only StudyHub (ISSUE 7).
//!
//! Every test arms a seeded, deterministic fault schedule (panics at
//! actor entry or in the journal-committed window, injected journal
//! I/O errors, torn writes, pool oracle failures), drives a hub
//! through it with a retrying client loop, and asserts the recovered
//! hub is **bitwise equal** to a fault-free twin driven through the
//! identical protocol: same trials, same pending set, same GP
//! hyperparameters, same next suggestion. Faults must surface as
//! typed errors or supervised restarts — never a hang, never an
//! unhandled panic at the API boundary.
//!
//! The failpoint registry is process-global, so every test that arms
//! it holds [`failpoint::exclusive`] for its whole body.

use dbe_bo::bo::StudyConfig;
use dbe_bo::hub::json::Json;
use dbe_bo::hub::{
    HubClient, HubConfig, Journal, ServeConfig, Server, StudyHub, StudyId,
    StudySnapshot, StudySpec, SyncPolicy,
};
use dbe_bo::optim::mso::MsoStrategy;
use dbe_bo::testing::failpoint::{
    self, configure, fires, FailAction, FailSpec, Trigger,
};
use dbe_bo::Error;
use std::path::PathBuf;
use std::sync::Arc;

fn quick_cfg() -> StudyConfig {
    StudyConfig {
        dim: 2,
        bounds: vec![(-5.0, 5.0); 2],
        n_trials: 40,
        n_startup: 4,
        restarts: 3,
        strategy: MsoStrategy::Dbe,
        fit_every: 2,
        ..StudyConfig::default()
    }
}

fn bowl(x: &[f64]) -> f64 {
    (x[0] - 0.5).powi(2) + (x[1] + 1.0).powi(2)
}

/// A hub sized for chaos: the restart budget is generous because these
/// tests assert recovery equivalence, not budget exhaustion (the
/// budget path has its own tests in `hub::tests`).
fn chaos_cfg(journal: Option<PathBuf>, pool_workers: usize) -> HubConfig {
    HubConfig { journal, pool_workers, restart_budget: 100, ..HubConfig::default() }
}

fn temp_journal(tag: &str) -> PathBuf {
    let path = std::env::temp_dir()
        .join(format!("dbe_bo_chaos_{tag}_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    path
}

/// Silence the default panic printer for *injected* panics (their
/// whole purpose is to be thrown and supervised) while keeping real
/// panics loud. Restores the default hook on drop.
struct QuietPanics;

impl QuietPanics {
    fn install() -> QuietPanics {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .is_some_and(|m| m.contains("injected panic"));
            if !injected {
                prev(info);
            }
        }));
        QuietPanics
    }
}

impl Drop for QuietPanics {
    fn drop(&mut self) {
        let _ = std::panic::take_hook();
    }
}

/// Errors a chaos client treats as transient: retry the same request.
/// Everything else (including `Error::Crashed`) is a test failure.
fn recoverable(e: &Error) -> bool {
    matches!(e, Error::Busy(_) | Error::Restarting(_)) || failpoint::is_injected(e)
}

/// Drive one study to `n_trials` completed trials with ask(q)/tell,
/// retrying through injected faults and supervised restarts. The
/// *committed* operation sequence is identical with or without faults
/// (failed requests commit nothing; a post-commit panic is replayed),
/// which is what makes the fault-free twin comparison meaningful.
fn drive(hub: &StudyHub, id: StudyId, n_trials: usize, q: usize) {
    let mut attempts = 0usize;
    loop {
        attempts += 1;
        assert!(attempts < 2000, "chaos driver did not converge in 2000 attempts");
        let snap = match hub.snapshot(id) {
            Ok(s) => s,
            Err(e) if recoverable(&e) => continue,
            Err(e) => panic!("snapshot must stay typed under chaos, got: {e}"),
        };
        if snap.trials.len() >= n_trials && snap.pending.is_empty() {
            return;
        }
        if let Some((tid, x)) = snap.pending.first().cloned() {
            match hub.tell(id, tid, bowl(&x)) {
                Ok(()) => {}
                Err(e) if recoverable(&e) => {}
                // A panic *after* the journal commit means the tell
                // landed; a raced retry then finds it already told.
                Err(Error::Hub(m)) if m.contains("is not pending") => {}
                Err(e) => panic!("tell must stay typed under chaos, got: {e}"),
            }
            continue;
        }
        let remaining = n_trials - snap.trials.len();
        match hub.ask(id, q.min(remaining)) {
            Ok(_) => {}
            Err(e) if recoverable(&e) => {}
            Err(e) => panic!("ask must stay typed under chaos, got: {e}"),
        }
    }
}

/// The bitwise-equivalence criterion. Deliberately excludes
/// `StudyStats`: retried requests legitimately redo acquisition work
/// (e.g. `fantasy_appends` counts attempts, not commits), and the
/// crash-only contract is about *state*, not effort.
fn assert_snapshots_bitwise_equal(tag: &str, a: &StudySnapshot, b: &StudySnapshot) {
    assert_eq!(a.trials.len(), b.trials.len(), "{tag}: trial count");
    for (i, (ta, tb)) in a.trials.iter().zip(&b.trials).enumerate() {
        assert_eq!(ta.x, tb.x, "{tag}: trial {i} suggestion differs");
        assert_eq!(
            ta.value.to_bits(),
            tb.value.to_bits(),
            "{tag}: trial {i} value differs"
        );
    }
    assert_eq!(a.pending, b.pending, "{tag}: pending set differs");
    assert_eq!(a.next_trial_id, b.next_trial_id, "{tag}: next trial id differs");
    assert_eq!(
        a.gp_params.log_len.to_bits(),
        b.gp_params.log_len.to_bits(),
        "{tag}: gp log_len differs"
    );
    assert_eq!(
        a.gp_params.log_sf2.to_bits(),
        b.gp_params.log_sf2.to_bits(),
        "{tag}: gp log_sf2 differs"
    );
    assert_eq!(
        a.gp_params.log_noise.to_bits(),
        b.gp_params.log_noise.to_bits(),
        "{tag}: gp log_noise differs"
    );
    match (&a.best, &b.best) {
        (None, None) => {}
        (Some(ba), Some(bb)) => {
            assert_eq!(ba.x, bb.x, "{tag}: best x differs");
            assert_eq!(ba.value.to_bits(), bb.value.to_bits(), "{tag}: best value");
            assert_eq!(ba.trial, bb.trial, "{tag}: best trial index");
        }
        _ => panic!("{tag}: one side has a best result, the other does not"),
    }
}

/// After state equality, the forward-looking criterion: the next ask
/// must be bitwise the suggestion the fault-free twin produces.
fn assert_next_ask_bitwise_equal(
    tag: &str,
    hub: &StudyHub,
    id: StudyId,
    twin: &StudyHub,
    twin_id: StudyId,
) {
    let a = hub.ask(id, 1).unwrap();
    let b = twin.ask(twin_id, 1).unwrap();
    assert_eq!(a[0].trial_id, b[0].trial_id, "{tag}: next trial id differs");
    for (xa, xb) in a[0].x.iter().zip(&b[0].x) {
        assert_eq!(xa.to_bits(), xb.to_bits(), "{tag}: next suggestion differs");
    }
}

/// Panics at the ask and tell handlers on a seeded periodic schedule:
/// every fault is supervised, every restart rebuilds from the actor's
/// in-memory segment, and the recovered hub is bitwise the fault-free
/// twin — including a second tenant sharing the hub.
#[test]
fn supervised_panic_storm_recovers_bitwise_to_fault_free_twin() {
    let _guard = failpoint::exclusive();
    let _quiet = QuietPanics::install();
    let n = 8;

    // Fault-free twin first (no points armed yet).
    let twin = StudyHub::open(chaos_cfg(None, 0)).unwrap();
    let twin_a = twin.create_study(StudySpec::new("a", quick_cfg(), 11)).unwrap();
    let twin_b = twin.create_study(StudySpec::new("b", quick_cfg(), 22)).unwrap();
    drive(&twin, twin_a, n, 2);
    drive(&twin, twin_b, n, 2);

    let hub = StudyHub::open(chaos_cfg(None, 0)).unwrap();
    let a = hub.create_study(StudySpec::new("a", quick_cfg(), 11)).unwrap();
    let b = hub.create_study(StudySpec::new("b", quick_cfg(), 22)).unwrap();
    configure(
        "hub::actor::ask",
        FailSpec::new(Trigger::EveryNth(3), FailAction::Panic("ask storm".into()))
            .with_max_fires(2),
    );
    configure(
        "hub::actor::tell",
        FailSpec::new(Trigger::EveryNth(4), FailAction::Panic("tell storm".into()))
            .with_max_fires(2),
    );
    drive(&hub, a, n, 2);
    drive(&hub, b, n, 2);
    failpoint::clear();

    assert!(hub.total_restarts() >= 2, "the storm must actually have fired");
    assert_eq!(hub.panic_log().len(), hub.total_restarts());
    assert!(hub.crashed_studies().is_empty(), "generous budget: nobody crashes");
    for (id, twin_id, tag) in [(a, twin_a, "a"), (b, twin_b, "b")] {
        let snap = hub.snapshot(id).unwrap();
        let twin_snap = twin.snapshot(twin_id).unwrap();
        assert_snapshots_bitwise_equal(tag, &snap, &twin_snap);
        assert_next_ask_bitwise_equal(tag, &hub, id, &twin, twin_id);
    }
}

/// The hardest window: a panic *after* the journal append but *before*
/// the in-memory mutation. The supervisor must rebuild from the
/// journal (which already holds the event), not from stale memory —
/// and a later process restart must agree bitwise.
#[test]
fn panic_in_committed_window_replays_from_journal_bitwise() {
    let _guard = failpoint::exclusive();
    let _quiet = QuietPanics::install();
    let n = 8;
    let path = temp_journal("commit_window");

    let twin = StudyHub::open(chaos_cfg(None, 0)).unwrap();
    let twin_id = twin.create_study(StudySpec::new("s", quick_cfg(), 42)).unwrap();
    drive(&twin, twin_id, n, 2);

    let hub = StudyHub::open(chaos_cfg(Some(path.clone()), 0)).unwrap();
    let id = hub.create_study(StudySpec::new("s", quick_cfg(), 42)).unwrap();
    configure(
        "hub::actor::ask::commit",
        FailSpec::new(Trigger::Nth(1), FailAction::Panic("post-commit".into())),
    );
    configure(
        "hub::actor::tell::commit",
        FailSpec::new(Trigger::Nth(1), FailAction::Panic("post-commit".into())),
    );
    drive(&hub, id, n, 2);
    failpoint::clear();

    assert!(hub.total_restarts() >= 2, "both commit-window panics fired");
    let snap = hub.snapshot(id).unwrap();
    let twin_snap = twin.snapshot(twin_id).unwrap();
    assert_snapshots_bitwise_equal("commit-window", &snap, &twin_snap);
    assert_next_ask_bitwise_equal("commit-window", &hub, id, &twin, twin_id);

    // Process-level restart on top of the supervised restarts: the
    // journal alone reconstructs the same state the twin reached.
    drop(hub);
    let reopened = StudyHub::open(chaos_cfg(Some(path.clone()), 0)).unwrap();
    let rid = reopened.find_study("s").expect("replayed study");
    let twin_now = twin.snapshot(twin_id).unwrap();
    assert_snapshots_bitwise_equal("reopen", &reopened.snapshot(rid).unwrap(), &twin_now);

    let _ = std::fs::remove_file(&path);
}

/// Injected journal append failures: the append is all-or-nothing, the
/// caller sees a typed injected error, a retry commits the identical
/// event, and both the live hub and a reopened one match the twin.
#[test]
fn journal_append_faults_are_typed_and_preserve_equivalence() {
    let _guard = failpoint::exclusive();
    let n = 8;
    let path = temp_journal("append_fault");

    let twin = StudyHub::open(chaos_cfg(None, 0)).unwrap();
    let twin_id = twin.create_study(StudySpec::new("s", quick_cfg(), 7)).unwrap();
    drive(&twin, twin_id, n, 2);

    let hub = StudyHub::open(chaos_cfg(Some(path.clone()), 0)).unwrap();
    let id = hub.create_study(StudySpec::new("s", quick_cfg(), 7)).unwrap();
    configure(
        "hub::journal::append",
        FailSpec::new(Trigger::EveryNth(4), FailAction::Error("disk hiccup".into()))
            .with_max_fires(3),
    );
    drive(&hub, id, n, 2);
    let fired = fires("hub::journal::append");
    failpoint::clear();

    assert!(fired >= 1, "the append fault schedule must have fired");
    assert_eq!(hub.total_restarts(), 0, "I/O errors are typed, not panics");
    assert_snapshots_bitwise_equal(
        "append-fault",
        &hub.snapshot(id).unwrap(),
        &twin.snapshot(twin_id).unwrap(),
    );
    assert_next_ask_bitwise_equal("append-fault", &hub, id, &twin, twin_id);

    drop(hub);
    let reopened = StudyHub::open(chaos_cfg(Some(path.clone()), 0)).unwrap();
    let rid = reopened.find_study("s").unwrap();
    assert_snapshots_bitwise_equal(
        "append-fault reopen",
        &reopened.snapshot(rid).unwrap(),
        &twin.snapshot(twin_id).unwrap(),
    );

    let _ = std::fs::remove_file(&path);
}

/// A torn write — half the line reaches the file, then the error
/// surfaces — must be clawed back by the journal so the on-disk prefix
/// stays exactly the acknowledged events, and the retried append lands
/// cleanly on the healed tail.
#[test]
fn torn_journal_write_truncates_back_and_heals() {
    let _guard = failpoint::exclusive();
    let n = 6;
    let path = temp_journal("torn");

    let twin = StudyHub::open(chaos_cfg(None, 0)).unwrap();
    let twin_id = twin.create_study(StudySpec::new("s", quick_cfg(), 5)).unwrap();
    drive(&twin, twin_id, n, 1);

    let hub = StudyHub::open(chaos_cfg(Some(path.clone()), 0)).unwrap();
    let id = hub.create_study(StudySpec::new("s", quick_cfg(), 5)).unwrap();
    configure(
        "hub::journal::torn",
        FailSpec::new(Trigger::Nth(2), FailAction::Error("power blip".into())),
    );
    drive(&hub, id, n, 1);
    let fired = fires("hub::journal::torn");
    failpoint::clear();

    assert_eq!(fired, 1, "exactly one torn write was injected");
    assert_snapshots_bitwise_equal(
        "torn",
        &hub.snapshot(id).unwrap(),
        &twin.snapshot(twin_id).unwrap(),
    );

    // The file parses end to end: the torn half never survived.
    drop(hub);
    let (_, events) = Journal::open(&path, SyncPolicy::Os).unwrap();
    assert_eq!(events.len(), 1 + n + n, "create + n asks + n tells, no debris");

    let _ = std::fs::remove_file(&path);
}

/// Satellite 4 — the torn-tail property. Truncate a valid journal at
/// *every* byte offset inside its final record: `Journal::open` must
/// replay exactly the untorn prefix (never panic, never invent or
/// drop acknowledged events). A corrupted *terminated* line, by
/// contrast, is acknowledged state gone bad and must fail the open
/// with a typed error.
#[test]
fn torn_tail_truncation_replays_prefix_at_every_offset() {
    let _guard = failpoint::exclusive();
    let n = 5;
    let path = temp_journal("tail_prop");

    {
        let hub = StudyHub::open(chaos_cfg(Some(path.clone()), 0)).unwrap();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(), 3)).unwrap();
        drive(&hub, id, n, 2);
    }
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(bytes.last(), Some(&b'\n'), "a clean journal ends terminated");
    let (_, full_events) = Journal::open(&path, SyncPolicy::Os).unwrap();
    let full_dbg: Vec<String> =
        full_events.iter().map(|e| format!("{e:?}")).collect();

    // Byte offset where the final record starts.
    let tail_start = bytes[..bytes.len() - 1]
        .iter()
        .rposition(|&b| b == b'\n')
        .map_or(0, |p| p + 1);
    assert!(bytes.len() - tail_start > 2, "final record is non-trivial");

    let cut_path = temp_journal("tail_prop_cut");
    for cut in tail_start..bytes.len() {
        std::fs::write(&cut_path, &bytes[..cut]).unwrap();
        let (journal, events) = Journal::open(&cut_path, SyncPolicy::Os)
            .unwrap_or_else(|e| panic!("cut at byte {cut}: open failed: {e}"));
        assert_eq!(
            events.len(),
            full_dbg.len() - 1,
            "cut at byte {cut}: exactly the torn tail is dropped"
        );
        for (i, ev) in events.iter().enumerate() {
            assert_eq!(
                format!("{ev:?}"),
                full_dbg[i],
                "cut at byte {cut}: replayed event {i} diverged"
            );
        }
        drop(journal);
        // Open healed the file back to the terminated prefix.
        assert_eq!(
            std::fs::read(&cut_path).unwrap(),
            &bytes[..tail_start],
            "cut at byte {cut}: torn bytes must be truncated away"
        );
    }

    // Corrupting a *terminated* line is not a torn tail: typed failure.
    let mut corrupt = bytes.clone();
    corrupt[tail_start] = b'#';
    std::fs::write(&cut_path, &corrupt).unwrap();
    match Journal::open(&cut_path, SyncPolicy::Os) {
        Err(Error::Hub(m)) => assert!(m.contains("corrupt"), "typed corruption: {m}"),
        Err(other) => panic!("expected typed Error::Hub corruption, got {other}"),
        Ok(_) => panic!("a corrupt terminated line must fail the open"),
    }

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&cut_path);
}

/// Satellite 4, extended to snapshot records (ISSUE 8): tear the tail
/// of a journal whose final record is a *snapshot*, at every byte
/// offset. The torn snapshot must be dropped exactly like any torn
/// line — never half-applied — and the healed journal must rebuild the
/// study bitwise from the raw events the snapshot would have
/// superseded.
#[test]
fn torn_snapshot_tail_truncation_replays_prefix_at_every_offset() {
    let _guard = failpoint::exclusive();
    let n = 6;
    let path = temp_journal("snap_tail_prop");

    let twin = StudyHub::open(chaos_cfg(None, 0)).unwrap();
    let twin_id = twin.create_study(StudySpec::new("s", quick_cfg(), 17)).unwrap();
    drive(&twin, twin_id, n, 2);

    {
        let hub = StudyHub::open(chaos_cfg(Some(path.clone()), 0)).unwrap();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(), 17)).unwrap();
        drive(&hub, id, n, 2);
        // An on-demand checkpoint does not rotate the segment, so the
        // snapshot is the final record of a single-file journal.
        hub.checkpoint(id).unwrap();
    }
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(bytes.last(), Some(&b'\n'), "a clean journal ends terminated");
    let tail_start = bytes[..bytes.len() - 1]
        .iter()
        .rposition(|&b| b == b'\n')
        .map_or(0, |p| p + 1);
    let tail = std::str::from_utf8(&bytes[tail_start..]).unwrap();
    assert!(tail.contains("\"ev\":\"snapshot\""), "final record is the snapshot");
    let (_, full_events) = Journal::open(&path, SyncPolicy::Os).unwrap();
    let full_dbg: Vec<String> =
        full_events.iter().map(|e| format!("{e:?}")).collect();

    let cut_path = temp_journal("snap_tail_prop_cut");
    for cut in tail_start..bytes.len() {
        std::fs::write(&cut_path, &bytes[..cut]).unwrap();
        let (journal, events) = Journal::open(&cut_path, SyncPolicy::Os)
            .unwrap_or_else(|e| panic!("cut at byte {cut}: open failed: {e}"));
        assert_eq!(
            events.len(),
            full_dbg.len() - 1,
            "cut at byte {cut}: exactly the torn snapshot is dropped"
        );
        for (i, ev) in events.iter().enumerate() {
            assert_eq!(
                format!("{ev:?}"),
                full_dbg[i],
                "cut at byte {cut}: replayed event {i} diverged"
            );
        }
        drop(journal);
        assert_eq!(
            std::fs::read(&cut_path).unwrap(),
            &bytes[..tail_start],
            "cut at byte {cut}: torn snapshot bytes must be truncated away"
        );
    }

    // Full-stack check at one representative cut: the hub that lost its
    // snapshot mid-write rebuilds from raw events, bitwise the twin —
    // including the next ask.
    let cut = tail_start + (bytes.len() - tail_start) / 2;
    std::fs::write(&path, &bytes[..cut]).unwrap();
    let hub = StudyHub::open(chaos_cfg(Some(path.clone()), 0)).unwrap();
    let id = hub.find_study("s").expect("replayed study");
    assert_snapshots_bitwise_equal(
        "torn snapshot",
        &hub.snapshot(id).unwrap(),
        &twin.snapshot(twin_id).unwrap(),
    );
    assert_next_ask_bitwise_equal("torn snapshot", &hub, id, &twin, twin_id);

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&cut_path);
}

/// Satellite 4 — the compaction commit point. A crash after the
/// replacement file is written but *before* the rename must leave the
/// old segments authoritative (the `.compact.tmp` debris is ignored);
/// after a completed compaction, dead segments at or below the new
/// floor are ignored even when their content is garbage. CI's
/// chaos-smoke job runs this test by name.
#[test]
fn mid_compaction_crash_keeps_old_segments_authoritative() {
    let _guard = failpoint::exclusive();
    let n = 6;
    let path = temp_journal("mid_compaction");
    // Periodic snapshots so the journal really has sealed segments.
    let cfg = || HubConfig {
        journal: Some(path.clone()),
        snapshot_every: 3,
        restart_budget: 100,
        ..HubConfig::default()
    };

    let twin = StudyHub::open(chaos_cfg(None, 0)).unwrap();
    let twin_id = twin.create_study(StudySpec::new("s", quick_cfg(), 21)).unwrap();
    drive(&twin, twin_id, n, 2);

    {
        let hub = StudyHub::open(cfg()).unwrap();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(), 21)).unwrap();
        drive(&hub, id, n, 2);
        assert!(hub.journal_snapshots() > 0, "rotation must have happened");

        // Power cut after the replacement file is durable but before
        // the rename: the commit point is never reached.
        configure(
            "hub::journal::compact",
            FailSpec::new(Trigger::Nth(1), FailAction::Error("power cut".into())),
        );
        let e = hub.compact().unwrap_err();
        assert!(failpoint::is_injected(&e), "typed injected failure, got {e}");
        failpoint::clear();
    }
    let tmp = PathBuf::from(format!("{}.compact.tmp", path.display()));
    assert!(tmp.exists(), "the crash left the replacement file behind");

    // Old segments + active file win; the debris is ignored.
    let hub = StudyHub::open(cfg()).unwrap();
    let id = hub.find_study("s").expect("replayed study");
    assert_snapshots_bitwise_equal(
        "mid-compaction crash",
        &hub.snapshot(id).unwrap(),
        &twin.snapshot(twin_id).unwrap(),
    );

    // Now let compaction commit, crash-free, and scribble over a dead
    // segment: at or below the floor it must be ignored on reopen.
    let stats = hub.compact().unwrap();
    assert!(stats.segments_removed >= 1, "sealed segments became dead");
    assert!(stats.events_after <= stats.events_before);
    drop(hub);
    std::fs::write(
        format!("{}.seg{:06}", path.display(), 1),
        "garbage from a dead compaction epoch",
    )
    .unwrap();

    let hub = StudyHub::open(cfg()).unwrap();
    let id = hub.find_study("s").expect("replayed study after compaction");
    assert_snapshots_bitwise_equal(
        "post-compaction reopen",
        &hub.snapshot(id).unwrap(),
        &twin.snapshot(twin_id).unwrap(),
    );
    assert_next_ask_bitwise_equal("post-compaction", &hub, id, &twin, twin_id);

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&tmp);
}

/// Faults inside the shared acquisition pool (submit rejection, oracle
/// batch failure) surface to the asking client as typed injected
/// errors before anything commits; retries converge to the fault-free
/// numbers, pool on both sides.
#[test]
fn pool_faults_are_typed_and_preserve_equivalence() {
    let _guard = failpoint::exclusive();
    let n = 8;

    let twin = StudyHub::open(chaos_cfg(None, 2)).unwrap();
    let twin_id = twin.create_study(StudySpec::new("s", quick_cfg(), 13)).unwrap();
    drive(&twin, twin_id, n, 2);

    let hub = StudyHub::open(chaos_cfg(None, 2)).unwrap();
    let id = hub.create_study(StudySpec::new("s", quick_cfg(), 13)).unwrap();
    configure(
        "hub::pool::oracle",
        FailSpec::new(Trigger::EveryNth(5), FailAction::Error("oracle down".into()))
            .with_max_fires(2),
    );
    configure(
        "hub::pool::submit",
        FailSpec::new(Trigger::Nth(3), FailAction::Error("queue full".into())),
    );
    drive(&hub, id, n, 2);
    let oracle_fired = fires("hub::pool::oracle");
    failpoint::clear();

    assert!(oracle_fired >= 1, "the oracle fault schedule must have fired");
    let pool = hub.pool_metrics().expect("pool is on");
    assert!(pool.failures >= 1, "worker-side failures are counted");
    assert_snapshots_bitwise_equal(
        "pool",
        &hub.snapshot(id).unwrap(),
        &twin.snapshot(twin_id).unwrap(),
    );
    assert_next_ask_bitwise_equal("pool", &hub, id, &twin, twin_id);
}

/// The wire keeps its shape when a study dies: with a zero restart
/// budget a supervised panic is terminal, the client reads typed
/// `crashed` frames (never a hang, never a torn connection), and
/// metrics keep answering with the crash visible to operators.
#[test]
fn wire_level_crash_answers_typed_frames_and_metrics_report_it() {
    let _guard = failpoint::exclusive();
    let _quiet = QuietPanics::install();

    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        ..ServeConfig::default()
    })
    .unwrap();
    let hub = Arc::new(
        StudyHub::open(HubConfig { restart_budget: 0, ..HubConfig::default() })
            .unwrap(),
    );
    server.install_hub(Arc::clone(&hub));
    let mut client = HubClient::connect(&server.local_addr().to_string()).unwrap();
    client.create(&StudySpec::new("w", quick_cfg(), 9)).unwrap();

    configure(
        "hub::actor::ask",
        FailSpec::new(Trigger::Always, FailAction::Panic("terminal".into())),
    );
    let e = client.ask("w", 1).unwrap_err();
    assert!(
        matches!(e, Error::Crashed(_)),
        "budget 0 makes the first panic terminal, got {e:?}"
    );
    failpoint::clear();

    // The study stays down (typed, idempotent) but the server lives.
    let e = client.ask("w", 1).unwrap_err();
    assert!(matches!(e, Error::Crashed(_)), "crashed is sticky, got {e:?}");
    let e = client.snapshot("w").unwrap_err();
    assert!(matches!(e, Error::Crashed(_)), "snapshot gate too, got {e:?}");

    let m = client.metrics().unwrap();
    assert_eq!(m.field("restarts").unwrap().as_u64().unwrap(), 0);
    let crashed = m.field("crashed").unwrap().as_arr().unwrap();
    assert_eq!(crashed, &[Json::Str("w".into())], "operators see the casualty");
    assert_eq!(hub.crashed_studies(), vec!["w".to_string()]);

    client.shutdown().unwrap();
    drop(client);
    server.join();
}

/// The flight recorder must be a PURE observer (ISSUE 9). Re-run the
/// panic-storm recovery scenario with the recorder ARMED on the
/// faulted hub while the fault-free twin runs without it: recovery
/// must still be bitwise identical (tracing feeds no RNG, no
/// suggestion), and the supervisor must attach a non-empty recorder
/// trail to every `PanicRecord` it files.
#[test]
fn armed_flight_recorder_never_perturbs_bitwise_equivalence() {
    let _rec = dbe_bo::obs::recorder::exclusive();
    let _guard = failpoint::exclusive();
    let _quiet = QuietPanics::install();
    let n = 8;

    // Fault-free twin, recorder disarmed.
    let twin = StudyHub::open(chaos_cfg(None, 0)).unwrap();
    let twin_id = twin.create_study(StudySpec::new("s", quick_cfg(), 33)).unwrap();
    drive(&twin, twin_id, n, 2);

    // Faulted hub with tracing on for the whole run.
    dbe_bo::obs::recorder::arm();
    let hub = StudyHub::open(chaos_cfg(None, 0)).unwrap();
    let id = hub.create_study(StudySpec::new("s", quick_cfg(), 33)).unwrap();
    configure(
        "hub::actor::ask",
        FailSpec::new(Trigger::EveryNth(3), FailAction::Panic("armed storm".into()))
            .with_max_fires(2),
    );
    drive(&hub, id, n, 2);
    failpoint::clear();
    dbe_bo::obs::recorder::disarm();

    assert!(hub.total_restarts() >= 1, "the storm must actually have fired");
    assert!(
        dbe_bo::obs::recorder::emitted() > 0,
        "an armed run must actually record events"
    );
    // The supervisor black box: every panic record carries the crashed
    // study's recent recorder events (the hub/ask span at minimum).
    for p in hub.panic_log() {
        assert!(
            !p.trail.is_empty(),
            "armed supervision must attach an event trail to {}",
            p.study
        );
    }

    assert_snapshots_bitwise_equal(
        "armed",
        &hub.snapshot(id).unwrap(),
        &twin.snapshot(twin_id).unwrap(),
    );
    assert_next_ask_bitwise_equal("armed", &hub, id, &twin, twin_id);
}

/// The health engine must be a PURE observer (ISSUE 10). A hub with
/// the ledger ON — queried mid-run, under a supervised panic storm —
/// must stay bitwise equal to a fault-free twin with the ledger OFF:
/// same trials, same GP hyperparameters, same next suggestion, and a
/// byte-identical journal. The ledger reads only committed state
/// post-commit; it must never feed RNG, fit schedules, or suggestions.
#[test]
fn health_engine_on_vs_off_is_bitwise_equivalent() {
    let _guard = failpoint::exclusive();
    let _quiet = QuietPanics::install();
    let n = 8;
    let path_on = temp_journal("health_on");
    let path_off = temp_journal("health_off");

    // Twin: ledger OFF, fault-free. Split the drive at the same point
    // as the faulted run so the committed sequences stay comparable.
    let off = StudyHub::open(HubConfig {
        health: false,
        ..chaos_cfg(Some(path_off.clone()), 0)
    })
    .unwrap();
    let off_id = off.create_study(StudySpec::new("s", quick_cfg(), 77)).unwrap();
    drive(&off, off_id, n / 2, 2);
    drive(&off, off_id, n, 2);

    // Subject: ledger ON (the default), panic storm armed, health
    // queried both mid-run and under the storm.
    let on = StudyHub::open(chaos_cfg(Some(path_on.clone()), 0)).unwrap();
    let on_id = on.create_study(StudySpec::new("s", quick_cfg(), 77)).unwrap();
    configure(
        "hub::actor::ask",
        FailSpec::new(Trigger::EveryNth(3), FailAction::Panic("health storm".into()))
            .with_max_fires(2),
    );
    drive(&on, on_id, n / 2, 2);
    let query_health = |hub: &StudyHub, id| loop {
        match hub.health(id) {
            Ok(h) => break h,
            Err(e) if recoverable(&e) => continue,
            Err(e) => panic!("health must stay typed under chaos, got: {e}"),
        }
    };
    let mid = query_health(&on, on_id);
    assert_eq!(mid.n_trials, n / 2, "mid-run report sees committed tells");
    drive(&on, on_id, n, 2);
    failpoint::clear();
    assert!(on.total_restarts() >= 1, "the storm must actually have fired");

    // The ON hub's report carries the ledger; the OFF hub's report is
    // the empty default (gated, not partially fed).
    let h_on = query_health(&on, on_id);
    assert_eq!(h_on.n_trials, n);
    let (best, _) = h_on.best.expect("ledger tracked the incumbent");
    let snap_best = on.snapshot(on_id).unwrap().best.unwrap().value;
    assert_eq!(best.to_bits(), snap_best.to_bits(), "ledger incumbent agrees");
    assert!(h_on.loo.is_some(), "a fitted GP yields LOO diagnostics");
    let h_off = query_health(&off, off_id);
    assert_eq!(h_off.n_trials, n, "report counts come from study state");
    assert!(h_off.best.is_none(), "health off: the ledger is never fed");
    assert!(h_off.loo.is_none() && h_off.qn.is_none() && h_off.flags.is_empty());

    assert_snapshots_bitwise_equal(
        "health",
        &on.snapshot(on_id).unwrap(),
        &off.snapshot(off_id).unwrap(),
    );
    assert_next_ask_bitwise_equal("health", &on, on_id, &off, off_id);

    // Committed-state equivalence extends to durability: the journals
    // must be byte-identical (the ledger journals nothing).
    drop(on);
    drop(off);
    assert_eq!(
        std::fs::read(&path_on).unwrap(),
        std::fs::read(&path_off).unwrap(),
        "health ledger must not perturb or extend the journal"
    );
    let _ = std::fs::remove_file(&path_on);
    let _ = std::fs::remove_file(&path_off);
}

/// Supervision lint (mirrors `no_dense_inverse_on_hot_paths`): every
/// thread inside the hub must be spawned through a named
/// `thread::Builder` so panics and joins are attributable. A bare
/// `std::thread::spawn` would be an unsupervised, anonymous thread.
/// CI's chaos-smoke job runs the same grep over `rust/src/hub/`.
#[test]
fn no_unsupervised_thread_spawn_in_hub_sources() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src/hub");
    let mut checked = 0;
    for entry in std::fs::read_dir(&dir).expect("rust/src/hub exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("rs") {
            continue;
        }
        let src = std::fs::read_to_string(&path).unwrap();
        assert!(
            !src.contains("std::thread::spawn"),
            "{} uses bare std::thread::spawn — use a named thread::Builder \
             so the supervisor can attribute panics",
            path.display()
        );
        checked += 1;
    }
    assert!(checked >= 5, "the hub module tree moved; update this lint");
}
