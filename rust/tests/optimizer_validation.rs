//! Broad L-BFGS-B / BFGS validation on standard test problems —
//! the solver substrate must be trustworthy before any paper claim
//! built on it means anything.

use dbe_bo::bbob::{self, Objective};
use dbe_bo::optim::bfgs::{Bfgs, BfgsOptions};
use dbe_bo::optim::lbfgsb::{Lbfgsb, LbfgsbOptions};
use dbe_bo::optim::{Ask, AskTellOptimizer, StopReason};
use dbe_bo::rng::Pcg64;
use dbe_bo::testing::forall;

fn drive<O: AskTellOptimizer>(
    opt: &mut O,
    f: &dyn Fn(&[f64]) -> (f64, Vec<f64>),
    cap: usize,
) -> StopReason {
    for _ in 0..cap {
        match opt.ask() {
            Ask::Evaluate(x) => {
                let (v, g) = f(&x);
                opt.tell(v, &g);
            }
            Ask::Done(r) => return r,
        }
    }
    panic!("no termination in {cap} evals");
}

#[test]
fn lbfgsb_rosenbrock_family() {
    // Multiple dimensions, multiple starts: all must reach the optimum.
    for d in [2usize, 3, 5, 8, 12] {
        let f = bbob::Rosenbrock::new(d);
        let mut rng = Pcg64::seeded(d as u64);
        for trial in 0..3 {
            let x0 = rng.uniform_vec(d, 0.0, 3.0);
            let mut opt = Lbfgsb::new(
                x0,
                f.bounds(),
                LbfgsbOptions { pgtol: 1e-9, ftol: 0.0, max_iters: 500, ..Default::default() },
            )
            .unwrap();
            drive(&mut opt, &|x| f.value_grad(x), 50_000);
            assert!(
                opt.best_f() < 1e-8,
                "rosenbrock d={d} trial={trial}: f={}",
                opt.best_f()
            );
        }
    }
}

#[test]
fn lbfgsb_beale_and_booth() {
    // Beale: minimum (3, 0.5), f=0, in box [-4.5, 4.5]².
    let beale = |x: &[f64]| {
        let (a, b) = (x[0], x[1]);
        let t1 = 1.5 - a + a * b;
        let t2 = 2.25 - a + a * b * b;
        let t3 = 2.625 - a + a * b * b * b;
        let v = t1 * t1 + t2 * t2 + t3 * t3;
        let g0 = 2.0 * t1 * (b - 1.0) + 2.0 * t2 * (b * b - 1.0) + 2.0 * t3 * (b * b * b - 1.0);
        let g1 = 2.0 * t1 * a + 2.0 * t2 * 2.0 * a * b + 2.0 * t3 * 3.0 * a * b * b;
        (v, vec![g0, g1])
    };
    let mut opt = Lbfgsb::new(
        vec![1.0, 1.0],
        vec![(-4.5, 4.5); 2],
        LbfgsbOptions { pgtol: 1e-10, ftol: 0.0, ..Default::default() },
    )
    .unwrap();
    drive(&mut opt, &beale, 20_000);
    assert!(opt.best_f() < 1e-10, "beale f={}", opt.best_f());
    assert!((opt.best_x()[0] - 3.0).abs() < 1e-3);
    assert!((opt.best_x()[1] - 0.5).abs() < 1e-3);

    // Booth: minimum (1, 3), f=0.
    let booth = |x: &[f64]| {
        let t1 = x[0] + 2.0 * x[1] - 7.0;
        let t2 = 2.0 * x[0] + x[1] - 5.0;
        (t1 * t1 + t2 * t2, vec![2.0 * t1 + 4.0 * t2, 4.0 * t1 + 2.0 * t2])
    };
    let mut opt = Lbfgsb::new(
        vec![-5.0, -5.0],
        vec![(-10.0, 10.0); 2],
        LbfgsbOptions::default(),
    )
    .unwrap();
    let reason = drive(&mut opt, &booth, 5000);
    assert!(reason.is_converged());
    assert!((opt.best_x()[0] - 1.0).abs() < 1e-4);
    assert!((opt.best_x()[1] - 3.0).abs() < 1e-4);
}

#[test]
fn lbfgsb_matches_bfgs_on_smooth_problems() {
    // Both solvers must land on the same optimum (not same path).
    let mut rng = Pcg64::seeded(31);
    for _ in 0..5 {
        let d = 2 + rng.below(4);
        let center: Vec<f64> = rng.uniform_vec(d, -1.0, 1.0);
        let w: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.5, 5.0)).collect();
        let c = center.clone();
        let wc = w.clone();
        let f = move |x: &[f64]| {
            let v: f64 =
                x.iter().zip(&c).zip(&wc).map(|((xi, ci), wi)| wi * (xi - ci).powi(2)).sum();
            let g: Vec<f64> =
                x.iter().zip(&c).zip(&wc).map(|((xi, ci), wi)| 2.0 * wi * (xi - ci)).collect();
            (v, g)
        };
        let x0 = rng.uniform_vec(d, -3.0, 3.0);
        let bounds = vec![(-5.0, 5.0); d];

        let mut l = Lbfgsb::new(x0.clone(), bounds.clone(), LbfgsbOptions::default()).unwrap();
        drive(&mut l, &f, 10_000);
        let mut b = Bfgs::new(x0, bounds, BfgsOptions::default()).unwrap();
        drive(&mut b, &f, 10_000);
        for i in 0..d {
            assert!(
                (l.best_x()[i] - b.best_x()[i]).abs() < 1e-4,
                "solvers disagree at coord {i}: {} vs {}",
                l.best_x()[i],
                b.best_x()[i]
            );
            assert!((l.best_x()[i] - center[i]).abs() < 1e-4);
        }
    }
}

#[test]
fn property_iterates_always_feasible() {
    // For any box and any smooth objective, every point the solver asks
    // to evaluate lies inside the box.
    forall("lbfgsb feasibility", 25, |g| {
        let d = g.size(6);
        let bounds: Vec<(f64, f64)> = (0..d)
            .map(|_| {
                let lo = g.f64_in(3.0);
                (lo, lo + 0.2 + g.f64_in(2.0).abs())
            })
            .collect();
        let x0: Vec<f64> = bounds.iter().map(|&(lo, hi)| 0.5 * (lo + hi)).collect();
        let center: Vec<f64> = bounds
            .iter()
            .map(|&(lo, hi)| lo + (hi - lo) * 1.5) // outside → active bounds
            .collect();
        let mut opt = Lbfgsb::new(
            x0,
            bounds.clone(),
            LbfgsbOptions { max_iters: 30, ..Default::default() },
        )
        .map_err(|e| e.to_string())?;
        for _ in 0..2000 {
            match opt.ask() {
                Ask::Evaluate(x) => {
                    for (i, (&xi, &(lo, hi))) in x.iter().zip(&bounds).enumerate() {
                        if xi < lo - 1e-12 || xi > hi + 1e-12 {
                            return Err(format!("infeasible coord {i}: {xi} not in [{lo},{hi}]"));
                        }
                    }
                    let v: f64 =
                        x.iter().zip(&center).map(|(a, b)| (a - b).powi(2)).sum();
                    let grad: Vec<f64> =
                        x.iter().zip(&center).map(|(a, b)| 2.0 * (a - b)).collect();
                    opt.tell(v, &grad);
                }
                Ask::Done(_) => return Ok(()),
            }
        }
        Err("no termination".into())
    });
}

#[test]
fn property_monotone_accepted_objective() {
    // The accepted-iterate objective sequence never increases (Wolfe
    // line search guarantees decrease).
    forall("lbfgsb monotonicity", 20, |g| {
        let d = 1 + g.size(5);
        let w: Vec<f64> = (0..d).map(|_| 0.5 + g.f64_in(3.0).abs()).collect();
        let x0 = g.vec_f64(d, 2.0);
        let mut opt = Lbfgsb::new(
            x0,
            vec![(-5.0, 5.0); d],
            LbfgsbOptions { max_iters: 40, ..Default::default() },
        )
        .map_err(|e| e.to_string())?;
        let mut accepted = f64::INFINITY;
        let mut last_iters = 0;
        for _ in 0..5000 {
            match opt.ask() {
                Ask::Evaluate(x) => {
                    let v: f64 = x.iter().zip(&w).map(|(xi, wi)| wi * xi * xi).sum();
                    let grad: Vec<f64> =
                        x.iter().zip(&w).map(|(xi, wi)| 2.0 * wi * xi).collect();
                    opt.tell(v, &grad);
                    if opt.n_iters() > last_iters {
                        last_iters = opt.n_iters();
                        let cur = opt.current_f();
                        if cur > accepted + 1e-12 {
                            return Err(format!("objective rose: {accepted} -> {cur}"));
                        }
                        accepted = cur;
                    }
                }
                Ask::Done(_) => return Ok(()),
            }
        }
        Err("no termination".into())
    });
}

#[test]
fn bbob_functions_are_optimizable_near_optimum() {
    // Start near x_opt; the solver should stay near it (sanity that the
    // BBOB landscapes are locally well-behaved for QN methods).
    for name in ["sphere", "attractive_sector"] {
        let f = bbob::by_name(name, 4, 3).unwrap();
        let fd = |x: &[f64]| (f.value(x), f.grad(x));
        // Perturbed start near the optimum: we don't know x_opt through
        // the trait, so start from a grid of random points and require
        // only that optimization never diverges.
        let mut rng = Pcg64::seeded(99);
        let x0 = rng.uniform_vec(4, -4.0, 4.0);
        let f0 = f.value(&x0);
        let mut opt = Lbfgsb::new(
            x0,
            f.bounds(),
            LbfgsbOptions { max_iters: 100, ..Default::default() },
        )
        .unwrap();
        drive(&mut opt, &fd, 20_000);
        assert!(opt.best_f() <= f0, "{name}: optimizer made things worse");
    }
}
