//! Adversarial protocol battery for `dbe-bo serve` (ISSUE 6).
//!
//! Every test drives a real loopback TCP server. The contract under
//! test: a request-level failure answers with a *typed error frame*
//! (`{"id":…,"ok":false,"error":<code>,"message":…}`) and the
//! connection keeps serving; only EOF, a transport error, or drain
//! closes it. Covers the malformed corpus, oversized-frame resync,
//! torn frames, byte-dribble slow clients, pipelining, unknown
//! study/trial, the journal-replay `starting` window, shutdown drain,
//! and a stalled half-frame that must not wedge that drain.

use dbe_bo::bo::StudyConfig;
use dbe_bo::coordinator::ServiceConfig;
use dbe_bo::hub::json::Json;
use dbe_bo::hub::proto::{encode_request, Request};
use dbe_bo::hub::{HubClient, HubConfig, ServeConfig, Server, StudyHub, StudySpec};
use dbe_bo::optim::mso::MsoStrategy;
use dbe_bo::Error;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn quick_cfg() -> StudyConfig {
    StudyConfig {
        dim: 2,
        bounds: vec![(-5.0, 5.0); 2],
        n_trials: 40,
        n_startup: 4,
        restarts: 3,
        strategy: MsoStrategy::Dbe,
        fit_every: 2,
        ..StudyConfig::default()
    }
}

fn bowl(x: &[f64]) -> f64 {
    (x[0] - 0.5).powi(2) + (x[1] + 1.0).powi(2)
}

/// Ephemeral-port server with an in-memory hub already installed.
fn start_server(max_frame: usize) -> (Server, String) {
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        max_frame,
    })
    .unwrap();
    server.install_hub(Arc::new(StudyHub::in_memory()));
    let addr = server.local_addr().to_string();
    (server, addr)
}

/// A raw line client — no protocol smarts, so it can speak garbage.
struct Raw {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Raw {
    fn connect(addr: &str) -> Raw {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        Raw { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn send_bytes(&mut self, bytes: &[u8]) {
        self.writer.write_all(bytes).unwrap();
    }

    fn send_line(&mut self, line: &str) {
        self.send_bytes(line.as_bytes());
        self.send_bytes(b"\n");
    }

    /// Read one reply frame; panics on EOF (`expect_eof` covers that).
    fn recv(&mut self) -> Json {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).unwrap();
        assert!(n > 0, "server closed the connection unexpectedly");
        Json::parse(line.trim_end_matches(['\n', '\r'])).expect("reply frame parses")
    }

    fn expect_eof(&mut self) {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).unwrap();
        assert_eq!(n, 0, "expected EOF, got reply {line:?}");
    }
}

/// Assert a frame is a typed error with the given code and echoed id.
fn assert_error(frame: &Json, code: &str, id: &Json) {
    assert_eq!(frame.field("ok").unwrap(), &Json::Bool(false), "frame: {frame}");
    assert_eq!(frame.field("error").unwrap().as_str().unwrap(), code, "frame: {frame}");
    assert_eq!(frame.field("id").unwrap(), id, "id must be echoed verbatim: {frame}");
    // Every error carries a human-readable message.
    assert!(!frame.field("message").unwrap().as_str().unwrap().is_empty());
}

fn assert_ok(frame: &Json) {
    assert_eq!(frame.field("ok").unwrap(), &Json::Bool(true), "frame: {frame}");
}

#[test]
fn adversarial_corpus_answers_typed_errors_and_keeps_serving() {
    let (server, addr) = start_server(1 << 20);
    let mut raw = Raw::connect(&addr);

    // (line, expected code, expected echoed id).
    let corpus: &[(&str, &str, Json)] = &[
        // Not JSON at all.
        ("{", "malformed", Json::Null),
        ("@@@@", "malformed", Json::Null),
        ("07", "malformed", Json::Null),
        // JSON, but not a request object.
        ("[]", "malformed", Json::Null),
        ("\"just a string\"", "malformed", Json::Null),
        // Objects with a bad shape: the id IS recoverable and echoed.
        ("{\"id\":1,\"op\":\"frobnicate\"}", "bad_request", Json::u64(1)),
        ("{\"id\":2}", "bad_request", Json::u64(2)),
        ("{\"id\":3,\"op\":\"ask\"}", "bad_request", Json::u64(3)),
        ("{\"id\":6,\"op\":\"ask\",\"study\":\"ghost\",\"q\":0}", "bad_request", Json::u64(6)),
        (
            "{\"id\":7,\"op\":\"tell\",\"study\":\"ghost\",\"trial\":0,\"value\":1e999}",
            "bad_request",
            Json::u64(7),
        ),
        ("{\"id\":8,\"op\":5}", "bad_request", Json::u64(8)),
        // Ids are opaque — non-numeric ids echo too.
        ("{\"id\":\"abc\",\"op\":\"nope\"}", "bad_request", Json::Str("abc".into())),
        // Well-formed requests against nonexistent state.
        ("{\"id\":4,\"op\":\"ask\",\"study\":\"ghost\"}", "unknown_study", Json::u64(4)),
        (
            "{\"id\":5,\"op\":\"tell\",\"study\":\"ghost\",\"trial\":0,\"value\":1}",
            "unknown_study",
            Json::u64(5),
        ),
    ];
    for (line, code, id) in corpus {
        raw.send_line(line);
        assert_error(&raw.recv(), code, id);
    }

    // A line that is not valid UTF-8.
    raw.send_bytes(&[0xff, 0xfe, 0x01, b'\n']);
    assert_error(&raw.recv(), "malformed", &Json::Null);

    // Blank and CRLF keep-alive lines are skipped, not answered.
    raw.send_bytes(b"\n\r\n");

    // The same connection still serves real work.
    raw.send_line("{\"id\":99,\"op\":\"metrics\"}");
    let frame = raw.recv();
    assert_ok(&frame);
    assert_eq!(frame.field("id").unwrap(), &Json::u64(99));
    let serve = frame.field("metrics").unwrap().field("serve").unwrap();
    let errors = serve.field("errors").unwrap().as_u64().unwrap();
    assert_eq!(
        errors,
        corpus.len() as u64 + 1,
        "every adversarial line was counted exactly once"
    );

    drop(raw);
    server.shutdown();
    let m = server.join();
    assert_eq!(m.requests, corpus.len() as u64 + 2, "blank lines are not requests");
}

#[test]
fn oversized_frames_reject_and_resync() {
    let (server, addr) = start_server(512);
    let mut raw = Raw::connect(&addr);

    // A 2 KiB line: whether it arrives whole (complete-line check) or
    // in pieces (unterminated-buffer check), exactly one `oversized`
    // frame comes back and the stream resynchronizes at the newline.
    let big = format!("{{\"op\":\"metrics\",\"pad\":\"{}\"}}", "x".repeat(2048));
    raw.send_line(&big);
    assert_error(&raw.recv(), "oversized", &Json::Null);

    // Back in sync: the next frame is served normally.
    raw.send_line("{\"id\":1,\"op\":\"metrics\"}");
    let frame = raw.recv();
    assert_ok(&frame);
    assert_eq!(frame.field("id").unwrap(), &Json::u64(1));

    drop(raw);
    server.shutdown();
    server.join();
}

#[test]
fn torn_frame_at_eof_is_dropped_silently() {
    let (server, addr) = start_server(1 << 20);

    // Half a request, then the client dies mid-frame.
    let mut raw = Raw::connect(&addr);
    raw.send_bytes(b"{\"id\":1,\"op\":\"met");
    raw.writer.shutdown(std::net::Shutdown::Write).unwrap();
    // The torn tail is dropped like a torn journal line: no reply, EOF.
    raw.expect_eof();

    // The worker survived and serves the next connection.
    let mut raw2 = Raw::connect(&addr);
    raw2.send_line("{\"id\":2,\"op\":\"metrics\"}");
    assert_ok(&raw2.recv());

    drop(raw2);
    server.shutdown();
    let m = server.join();
    assert_eq!(m.requests, 1, "the torn frame never became a request");
}

#[test]
fn pipelined_requests_answer_in_order() {
    let (server, addr) = start_server(1 << 20);
    let mut raw = Raw::connect(&addr);

    let spec = StudySpec::new("pipe", quick_cfg(), 7);
    let mut batch = Vec::new();
    for (id, req) in [
        (10, Request::Metrics { prom: false }),
        (11, Request::Create(Box::new(spec))),
        (12, Request::Ask { study: "pipe".into(), q: 2 }),
    ] {
        batch.extend_from_slice(encode_request(id, &req).to_string().as_bytes());
        batch.push(b'\n');
    }
    // One write, three frames: responses come back in request order.
    raw.send_bytes(&batch);
    for expect_id in [10u64, 11, 12] {
        let frame = raw.recv();
        assert_ok(&frame);
        assert_eq!(frame.field("id").unwrap(), &Json::u64(expect_id));
        if expect_id == 12 {
            let sugs = frame.field("suggestions").unwrap().as_arr().unwrap();
            assert_eq!(sugs.len(), 2, "ask q=2 returns two suggestions");
        }
    }

    drop(raw);
    server.shutdown();
    server.join();
}

#[test]
fn tell_for_never_asked_trial_is_unknown_trial() {
    let (server, addr) = start_server(1 << 20);
    let mut client = HubClient::connect(&addr).unwrap();
    client.create(&StudySpec::new("t", quick_cfg(), 3)).unwrap();

    let err = client.tell("t", 999, 1.0).unwrap_err();
    match err {
        Error::Hub(msg) => {
            assert!(msg.starts_with("unknown_trial"), "typed code first: {msg}")
        }
        other => panic!("expected Error::Hub(unknown_trial: …), got {other:?}"),
    }

    // The study is unharmed: a real ask/tell round still works.
    let sugs = client.ask("t", 1).unwrap();
    client.tell("t", sugs[0].trial_id, bowl(&sugs[0].x)).unwrap();

    drop(client);
    server.shutdown();
    server.join();
}

/// The replay race (ISSUE 6 fix): the listener owns the port *before*
/// journal replay, and clients that connect during replay get a typed
/// `starting` frame — never a connection refusal, never a half-replayed
/// study.
#[test]
fn client_during_journal_replay_gets_starting_then_replayed_state() {
    let path = std::env::temp_dir()
        .join(format!("dbe_bo_serve_proto_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let hub_cfg = || HubConfig {
        journal: Some(path.clone()),
        pool_workers: 0,
        service: ServiceConfig::default(),
        mailbox_cap: 0,
        ..HubConfig::default()
    };

    // Session 1: journal a study with six completed trials.
    {
        let hub = StudyHub::open(hub_cfg()).unwrap();
        let id = hub.create_study(StudySpec::new("s0", quick_cfg(), 42)).unwrap();
        for _ in 0..6 {
            let sug = hub.ask(id, 1).unwrap().pop().unwrap();
            hub.tell(id, sug.trial_id, bowl(&sug.x)).unwrap();
        }
    }

    // Session 2: the serve startup ordering — bind first, replay after.
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        max_frame: 1 << 20,
    })
    .unwrap();
    let addr = server.local_addr().to_string();

    // The port is live but the hub is not installed yet (replay still
    // "running"): study ops answer `starting`, metrics answers with
    // ready=false so operators can watch.
    let mut raw = Raw::connect(&addr);
    raw.send_line("{\"id\":1,\"op\":\"ask\",\"study\":\"s0\"}");
    assert_error(&raw.recv(), "starting", &Json::u64(1));
    raw.send_line("{\"id\":2,\"op\":\"metrics\"}");
    let frame = raw.recv();
    assert_ok(&frame);
    let ready = frame.field("metrics").unwrap().field("ready").unwrap();
    assert_eq!(ready, &Json::Bool(false));

    // Replay finishes; the same connection now sees the full study.
    let hub = Arc::new(StudyHub::open(hub_cfg()).unwrap());
    server.install_hub(Arc::clone(&hub));

    raw.send_line("{\"id\":3,\"op\":\"snapshot\",\"study\":\"s0\"}");
    let frame = raw.recv();
    assert_ok(&frame);
    let snap = frame.field("snapshot").unwrap();
    assert_eq!(snap.field("trials").unwrap().as_arr().unwrap().len(), 6);
    assert_eq!(snap.field("name").unwrap().as_str().unwrap(), "s0");

    raw.send_line("{\"id\":4,\"op\":\"ask\",\"study\":\"s0\"}");
    let frame = raw.recv();
    assert_ok(&frame);
    assert_eq!(frame.field("suggestions").unwrap().as_arr().unwrap().len(), 1);

    drop(raw);
    server.shutdown();
    server.join();
    drop(hub);
    let _ = std::fs::remove_file(&path);
}

/// End-to-end `health` wire op (ISSUE 10) — the data path behind
/// `dbe-bo top`: drive a study over loopback, then assert the health
/// frame carries the ledger (incumbent, LOO diagnostics, QN quality,
/// flags array), unknown studies answer a typed frame, and the
/// `dbe_study_*` gauge families show up in both metrics formats.
#[test]
fn health_op_reports_the_ledger_over_the_wire() {
    let (server, addr) = start_server(1 << 20);
    let mut client = HubClient::connect(&addr).unwrap();
    client.create(&StudySpec::new("h", quick_cfg(), 5)).unwrap();

    // Before any tells the report exists with an empty ledger side.
    let h = client.health("h").unwrap();
    assert_eq!(h.field("n_trials").unwrap().as_u64().unwrap(), 0);
    assert_eq!(h.field("best").unwrap(), &Json::Null);
    assert!(h.field("flags").unwrap().as_arr().unwrap().is_empty());

    let mut told_best = f64::INFINITY;
    for _ in 0..8 {
        let sugs = client.ask("h", 1).unwrap();
        let v = bowl(&sugs[0].x);
        told_best = told_best.min(v);
        client.tell("h", sugs[0].trial_id, v).unwrap();
    }

    let h = client.health("h").unwrap();
    assert_eq!(h.field("n_trials").unwrap().as_u64().unwrap(), 8);
    assert_eq!(h.field("pending").unwrap().as_u64().unwrap(), 0);
    let best = h.field("best").unwrap();
    let bv = best.field("value").unwrap().as_f64().unwrap();
    assert_eq!(bv.to_bits(), told_best.to_bits(), "ledger incumbent is the min tell");
    assert!(best.field("tell").unwrap().as_u64().unwrap() >= 1);
    // n_startup=4, fit_every=2 ⇒ the GP is fitted and LOO is live.
    let loo = h.field("loo").unwrap();
    assert!(loo.field("n").unwrap().as_u64().unwrap() >= 4);
    assert!(loo.field("lpd").unwrap().as_f64().unwrap().is_finite());
    // Model-based asks ran the multi-start optimizer, so QN quality
    // telemetry is populated.
    let qn = h.field("qn").unwrap();
    assert!(qn.field("total").unwrap().as_u64().unwrap() >= 1);
    h.field("flags").unwrap().as_arr().unwrap();

    // Unknown study: typed error frame, connection keeps serving.
    let mut raw = Raw::connect(&addr);
    raw.send_line("{\"id\":9,\"op\":\"health\",\"study\":\"nope\"}");
    assert_error(&raw.recv(), "unknown_study", &Json::u64(9));

    // The per-study gauges reach both metrics formats.
    let m = client.metrics().unwrap();
    assert!(m.field("serve").unwrap().field("healths").unwrap().as_u64().unwrap() >= 2);
    let stats = m.field("study_stats").unwrap().as_arr().unwrap();
    let st = stats[0].field("best").unwrap().as_f64().unwrap();
    assert_eq!(st.to_bits(), told_best.to_bits(), "study_stats gauge agrees");
    let prom = client.metrics_prom().unwrap();
    for family in ["dbe_study_best", "dbe_study_regret", "dbe_study_stall", "dbe_study_flags"]
    {
        assert!(
            prom.contains(&format!("{family}{{study=\"h\"}}")),
            "prom output missing {family}:\n{prom}"
        );
    }
    assert!(prom.contains("dbe_study_loo_lpd{study=\"h\"}"));
    assert!(prom.contains("# HELP"), "registry families carry HELP lines");

    drop(raw);
    drop(client);
    server.shutdown();
    server.join();
}

#[test]
fn shutdown_frame_drains_idempotently() {
    let (server, addr) = start_server(1 << 20);

    let mut client = HubClient::connect(&addr).unwrap();
    client.create(&StudySpec::new("d", quick_cfg(), 5)).unwrap();
    client.shutdown().unwrap();
    // Idempotent: a second shutdown on the draining server still
    // answers ok (it may race the connection close — EOF is also fine).
    let _ = client.shutdown();
    // New work is refused with a typed frame or the connection is gone.
    assert!(client.ask("d", 1).is_err(), "a draining server accepts no new work");
    drop(client);

    let m = server.join();
    assert!(m.shutdowns >= 1);
    assert_eq!(m.creates, 1);
}

/// A client slower than the worker's 25ms read timeout: one byte every
/// ~10ms means several idle ticks land mid-frame. The worker must
/// treat each timeout as a keep-alive tick, accumulate the partial
/// line across ticks, and answer exactly one well-framed reply when
/// the newline finally arrives.
#[test]
fn byte_dribble_across_read_timeouts_gets_a_well_framed_reply() {
    let (server, addr) = start_server(1 << 20);
    let mut raw = Raw::connect(&addr);

    let line = b"{\"id\":21,\"op\":\"metrics\"}\n";
    for &b in line.iter() {
        raw.send_bytes(&[b]);
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let frame = raw.recv();
    assert_ok(&frame);
    assert_eq!(frame.field("id").unwrap(), &Json::u64(21));

    // The stream stayed in sync: a fast follow-up is served normally.
    raw.send_line("{\"id\":22,\"op\":\"metrics\"}");
    let frame = raw.recv();
    assert_ok(&frame);
    assert_eq!(frame.field("id").unwrap(), &Json::u64(22));

    drop(raw);
    server.shutdown();
    let m = server.join();
    assert_eq!(m.requests, 2, "the dribbled frame was counted exactly once");
}

/// A stalled half-frame must not wedge a drain: the client sends half
/// a request and then goes silent — no newline, no EOF — while the
/// operator requests shutdown. Only *complete* frames count as
/// in-flight work, so the worker hangs up on its next idle tick
/// instead of waiting forever for a newline that never comes.
#[test]
fn stalled_half_frame_does_not_wedge_drain() {
    let (server, addr) = start_server(1 << 20);
    let mut raw = Raw::connect(&addr);
    raw.send_bytes(b"{\"id\":1,\"op\":\"met");

    // Give the worker a tick to buffer the partial line, then drain.
    std::thread::sleep(std::time::Duration::from_millis(30));
    server.shutdown();
    let waiter = std::thread::Builder::new()
        .name("test-drain-waiter".into())
        .spawn(move || server.join())
        .unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while !waiter.is_finished() {
        assert!(
            std::time::Instant::now() < deadline,
            "drain wedged behind a stalled half-frame"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let m = waiter.join().unwrap();
    assert_eq!(m.requests, 0, "the stalled half-frame never became a request");
    // The server hung up without answering the torn frame.
    raw.expect_eof();
}
