//! AOT/PJRT ↔ native parity: the Pallas/JAX artifact must compute the
//! same −LogEI values and gradients as the native Rust GP stack, and
//! the whole MSO engine must produce the same trajectories over either
//! oracle.
//!
//! Requires `make artifacts`; tests self-skip (with a loud message) if
//! the manifest is absent so `cargo test` works on a fresh checkout.

use dbe_bo::batcheval::{BatchAcqEvaluator, NativeGpEvaluator};
use dbe_bo::gp::{GpParams, GpRegressor};
use dbe_bo::optim::lbfgsb::LbfgsbOptions;
use dbe_bo::optim::mso::{run_mso, MsoConfig, MsoStrategy};
use dbe_bo::rng::Pcg64;
use dbe_bo::runtime::{Manifest, PjrtEvaluator, PjrtRuntime};
use std::path::Path;

/// The artifacts AND a working PJRT client — `None` (with a loud
/// message) if either is missing, so `cargo test` self-skips both on a
/// fresh checkout and in the default build whose PJRT client is the
/// always-unavailable stub.
fn setup() -> Option<(Manifest, PjrtRuntime)> {
    let manifest = match Manifest::load(Path::new("artifacts")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP pjrt parity tests: {e}");
            return None;
        }
    };
    match PjrtRuntime::cpu() {
        Ok(rt) => Some((manifest, rt)),
        Err(e) => {
            eprintln!("SKIP pjrt parity tests: {e}");
            None
        }
    }
}

/// GP with controlled hyperparameters: parity must be tested on a
/// well-conditioned posterior. (With fitted, near-interpolating
/// hyperparameters — noise at its floor, σ_f² ≫ 1 — the variance
/// cancellation `σ_f² − k*ᵀK⁻¹k*` has fewer correct digits than the
/// parity tolerance in EITHER engine; see the noise-floor note in
/// `GpParams::fit_bounds`.)
fn fitted_gp(n: usize, d: usize, seed: u64) -> GpRegressor {
    let mut rng = Pcg64::seeded(seed);
    let x: Vec<Vec<f64>> = (0..n).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
    let y: Vec<f64> = x
        .iter()
        .map(|p| {
            let s: f64 = p.iter().map(|v| (v - 0.4).powi(2)).sum();
            s + 0.05 * (7.0 * p[0]).sin()
        })
        .collect();
    let params = GpParams {
        log_len: (0.4f64).ln(),
        log_sf2: 0.0,
        log_noise: (1e-4f64).ln(),
    };
    GpRegressor::with_params(x, &y, params).unwrap()
}

#[test]
fn pjrt_matches_native_values_and_grads() {
    let Some((manifest, runtime)) = setup() else { return };

    for (n, d, seed) in [(12usize, 2usize, 1u64), (30, 2, 2), (20, 5, 3), (61, 5, 4)] {
        let gp = fitted_gp(n, d, seed);
        let native = NativeGpEvaluator::new(&gp);
        let pjrt = PjrtEvaluator::from_gp(&runtime, &manifest, &gp).expect("pjrt evaluator");

        let mut rng = Pcg64::seeded(100 + seed);
        let qs: Vec<Vec<f64>> = (0..10).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
        let (nv, ng) = native.eval_batch(&qs).unwrap();
        let (pv, pg) = pjrt.eval_batch(&qs).unwrap();

        for i in 0..qs.len() {
            let scale = 1.0f64.max(nv[i].abs());
            assert!(
                (nv[i] - pv[i]).abs() < 1e-7 * scale,
                "n={n} d={d} value mismatch at {i}: native {} vs pjrt {}",
                nv[i],
                pv[i]
            );
            for k in 0..d {
                let gscale = 1.0f64.max(ng[i][k].abs());
                assert!(
                    (ng[i][k] - pg[i][k]).abs() < 1e-6 * gscale,
                    "n={n} d={d} grad mismatch at ({i},{k}): {} vs {}",
                    ng[i][k],
                    pg[i][k]
                );
            }
        }
    }
}

#[test]
fn pjrt_handles_partial_and_oversized_batches() {
    let Some((manifest, runtime)) = setup() else { return };
    let gp = fitted_gp(15, 2, 9);
    let native = NativeGpEvaluator::new(&gp);
    let pjrt = PjrtEvaluator::from_gp(&runtime, &manifest, &gp).unwrap();

    let mut rng = Pcg64::seeded(77);
    // 3 points (< compiled B=10) and 23 points (> B, chunked).
    for count in [1usize, 3, 10, 23] {
        let qs: Vec<Vec<f64>> = (0..count).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let (nv, _) = native.eval_batch(&qs).unwrap();
        let (pv, _) = pjrt.eval_batch(&qs).unwrap();
        assert_eq!(pv.len(), count);
        for i in 0..count {
            assert!(
                (nv[i] - pv[i]).abs() < 1e-7 * nv[i].abs().max(1.0),
                "count={count} idx={i}"
            );
        }
    }
}

#[test]
fn bucket_selection_grows_with_n() {
    let Some((manifest, runtime)) = setup() else { return };
    let small = PjrtEvaluator::from_gp(&runtime, &manifest, &fitted_gp(10, 2, 5)).unwrap();
    let large = PjrtEvaluator::from_gp(&runtime, &manifest, &fitted_gp(100, 2, 6)).unwrap();
    assert!(small.bucket().0 < large.bucket().0);
}

#[test]
fn mso_over_pjrt_matches_native_trajectories() {
    // The full-stack equivalence: D-BE over the AOT artifact must land
    // on the same optima as D-BE over the native oracle (same math,
    // different engine), and D-BE == SEQ. OPT. within each engine.
    let Some((manifest, runtime)) = setup() else { return };
    let gp = fitted_gp(25, 2, 11);
    let native = NativeGpEvaluator::new(&gp);
    let pjrt = PjrtEvaluator::from_gp(&runtime, &manifest, &gp).unwrap();

    let mut rng = Pcg64::seeded(13);
    let x0s: Vec<Vec<f64>> = (0..6).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
    let cfg = MsoConfig {
        bounds: vec![(0.0, 1.0); 2],
        lbfgsb: LbfgsbOptions { pgtol: 1e-6, ..Default::default() },
    };

    let dbe_native = run_mso(MsoStrategy::Dbe, &native, &x0s, &cfg).unwrap();
    let dbe_pjrt = run_mso(MsoStrategy::Dbe, &pjrt, &x0s, &cfg).unwrap();
    let seq_pjrt = run_mso(MsoStrategy::SeqOpt, &pjrt, &x0s, &cfg).unwrap();

    // Across engines: same optimum to float-noise (trajectories can
    // diverge late; endpoints of the argmax restart must agree).
    assert!(
        (dbe_native.best_f - dbe_pjrt.best_f).abs() < 1e-5 * dbe_native.best_f.abs().max(1.0),
        "native {} vs pjrt {}",
        dbe_native.best_f,
        dbe_pjrt.best_f
    );
    // Within the PJRT engine: exact D-BE == SEQ equivalence.
    for (a, b) in seq_pjrt.restarts.iter().zip(&dbe_pjrt.restarts) {
        assert_eq!(a.x, b.x, "D-BE must replay SEQ exactly over the same oracle");
        assert_eq!(a.iters, b.iters);
    }
}
