//! StudyHub acceptance equivalences (ISSUE 5):
//!
//! 1. A single-study hub driven ask(q=1)/tell in order bitwise-
//!    reproduces the blocking `Study::optimize` trajectory — same
//!    trials, same suggestions, same `StudyStats` fit split — with the
//!    shared acquisition pool both off and on (pool routing must be
//!    invisible to the numbers).
//! 2. Journal replay after a simulated crash reconstructs the study
//!    state bitwise (history, pending set, fit split, warm-started GP
//!    hyperparameters) and the next ask produces the identical
//!    suggestion an uninterrupted hub would have produced.
//! 3. (ISSUE 6) The TCP serving path is numerically invisible: a study
//!    driven through `Server` + `HubClient` over real loopback sockets
//!    bitwise-reproduces an in-process twin — suggestions, snapshot
//!    wire form, and journal bytes.
//! 4. (ISSUE 8) Snapshot records change only where replay *starts*,
//!    never where it lands: a hub resumed from its newest snapshot, a
//!    hub resumed by full event replay, and an uninterrupted twin agree
//!    bitwise, including the next ask after resume.

use dbe_bo::bo::{Study, StudyConfig};
use dbe_bo::coordinator::ServiceConfig;
use dbe_bo::hub::proto::snapshot_to_json;
use dbe_bo::hub::{
    HubClient, HubConfig, ServeConfig, Server, StudyHub, StudySnapshot, StudySpec,
    Suggestion,
};
use dbe_bo::optim::mso::MsoStrategy;
use std::sync::Arc;

fn quick_cfg(fit_every: usize) -> StudyConfig {
    StudyConfig {
        dim: 2,
        bounds: vec![(-5.0, 5.0); 2],
        n_trials: 40,
        n_startup: 4,
        restarts: 3,
        strategy: MsoStrategy::Dbe,
        fit_every,
        ..StudyConfig::default()
    }
}

fn bowl(x: &[f64]) -> f64 {
    (x[0] - 0.5).powi(2) + (x[1] + 1.0).powi(2)
}

fn assert_gp_params_bitwise(a: &StudySnapshot, b: &StudySnapshot) {
    assert_eq!(a.gp_params.log_len.to_bits(), b.gp_params.log_len.to_bits());
    assert_eq!(a.gp_params.log_sf2.to_bits(), b.gp_params.log_sf2.to_bits());
    assert_eq!(a.gp_params.log_noise.to_bits(), b.gp_params.log_noise.to_bits());
}

#[test]
fn hub_ask1_in_order_bitwise_reproduces_study_run() {
    // fit_every = 2 exercises both the boundary full-fit path and the
    // incremental refit_append path through the hub.
    for pool_workers in [0, 2] {
        let cfg = quick_cfg(2);
        let mut study = Study::new(cfg.clone(), 42);
        let n_trials = 12;
        for _ in 0..n_trials {
            let x = study.suggest().unwrap();
            let y = bowl(&x);
            study.observe(x, y);
        }

        let hub = StudyHub::open(HubConfig {
            journal: None,
            pool_workers,
            service: ServiceConfig::default(),
            mailbox_cap: 0,
            ..HubConfig::default()
        })
        .unwrap();
        let id = hub.create_study(StudySpec::new("s", cfg, 42)).unwrap();
        for _ in 0..n_trials {
            let batch = hub.ask(id, 1).unwrap();
            assert_eq!(batch.len(), 1);
            let Suggestion { trial_id, x } = batch.into_iter().next().unwrap();
            hub.tell(id, trial_id, bowl(&x)).unwrap();
        }

        let snap = hub.snapshot(id).unwrap();
        assert_eq!(snap.trials.len(), study.trials().len());
        for (i, (a, b)) in snap.trials.iter().zip(study.trials()).enumerate() {
            assert_eq!(a.x, b.x, "pool={pool_workers}: trial {i} suggestion differs");
            assert_eq!(a.value.to_bits(), b.value.to_bits());
        }
        // The StudyStats fit split — the fit engine must have run the
        // exact same schedule through the hub.
        assert_eq!(snap.stats.fit_full, study.stats.fit_full);
        assert_eq!(snap.stats.fit_incremental, study.stats.fit_incremental);
        assert_eq!(snap.stats.fantasy_appends, 0, "q=1 in order never fantasizes");
        assert_eq!(snap.stats.iters, study.stats.iters);
        assert_eq!(snap.stats.n_batches, study.stats.n_batches);
        assert_eq!(snap.stats.n_points, study.stats.n_points);
        // Warm-started hyperparameter chain (fit-engine state) matches.
        assert_eq!(snap.gp_params.log_len.to_bits(), study.gp_params().log_len.to_bits());
        assert_eq!(snap.gp_params.log_sf2.to_bits(), study.gp_params().log_sf2.to_bits());
        assert_eq!(
            snap.gp_params.log_noise.to_bits(),
            study.gp_params().log_noise.to_bits()
        );
        let hub_best = snap.best.unwrap();
        let study_best = study.best().unwrap();
        assert_eq!(hub_best.x, study_best.x);
        assert_eq!(hub_best.value.to_bits(), study_best.value.to_bits());
        assert_eq!(hub_best.trial, study_best.trial);
    }
}

/// Drive `hub` and `twin` through the identical protocol, asserting
/// every suggestion matches bitwise along the way.
fn drive_in_lockstep(
    hub: &StudyHub,
    hub_id: dbe_bo::hub::StudyId,
    twin: &StudyHub,
    twin_id: dbe_bo::hub::StudyId,
    asks: &[usize],
    tell_reversed: bool,
) {
    for &q in asks {
        let a = hub.ask(hub_id, q).unwrap();
        let b = twin.ask(twin_id, q).unwrap();
        assert_eq!(a.len(), b.len());
        let mut batch: Vec<(u64, Vec<f64>)> = Vec::new();
        for (sa, sb) in a.iter().zip(&b) {
            assert_eq!(sa.trial_id, sb.trial_id);
            assert_eq!(sa.x, sb.x, "journaled and twin suggestions must match");
            batch.push((sa.trial_id, sa.x.clone()));
        }
        if tell_reversed {
            batch.reverse();
        }
        for (trial_id, x) in batch {
            let y = bowl(&x);
            hub.tell(hub_id, trial_id, y).unwrap();
            twin.tell(twin_id, trial_id, y).unwrap();
        }
    }
}

#[test]
fn journal_replay_bitwise_resumes_after_simulated_crash() {
    let path = std::env::temp_dir()
        .join(format!("dbe_bo_hub_equiv_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);

    let cfg = quick_cfg(2);
    let spec = StudySpec::new("serving", cfg.clone(), 77);

    // The uninterrupted reference: same protocol, no journal, no crash.
    let twin = StudyHub::in_memory();
    let twin_id = twin.create_study(spec.clone()).unwrap();

    // The journaled hub that will "crash".
    let crashed_pending;
    {
        let hub = StudyHub::open(HubConfig {
            journal: Some(path.clone()),
            pool_workers: 0,
            service: ServiceConfig::default(),
            mailbox_cap: 0,
            ..HubConfig::default()
        })
        .unwrap();
        let id = hub.create_study(spec).unwrap();
        // Startup + model-based phase, including an out-of-order-told
        // q=2 batch (fantasy path + completion order ≠ ask order).
        drive_in_lockstep(&hub, id, &twin, twin_id, &[1, 1, 1, 1, 2, 1, 2], true);
        // One more ask that never gets told: pending at crash time.
        let a = hub.ask(id, 1).unwrap();
        let b = twin.ask(twin_id, 1).unwrap();
        assert_eq!(a[0].x, b[0].x);
        assert_eq!(a[0].trial_id, b[0].trial_id);
        crashed_pending = (a[0].trial_id, a[0].x.clone());
        // Drop without telling = the simulated crash.
    }

    // Reopen: replay must reconstruct everything bitwise.
    let hub = StudyHub::open(HubConfig {
        journal: Some(path.clone()),
        pool_workers: 0,
        service: ServiceConfig::default(),
        mailbox_cap: 0,
        ..HubConfig::default()
    })
    .unwrap();
    let id = hub.find_study("serving").expect("replayed study");
    let snap = hub.snapshot(id).unwrap();
    let twin_snap = twin.snapshot(twin_id).unwrap();

    assert_eq!(snap.trials.len(), twin_snap.trials.len());
    for (a, b) in snap.trials.iter().zip(&twin_snap.trials) {
        assert_eq!(a.x, b.x);
        assert_eq!(a.value.to_bits(), b.value.to_bits());
    }
    assert_eq!(snap.pending, twin_snap.pending, "pending set must survive the crash");
    assert_eq!(snap.pending, vec![crashed_pending]);
    assert_eq!(snap.next_trial_id, twin_snap.next_trial_id);
    assert_eq!(snap.stats.fit_full, twin_snap.stats.fit_full, "replayed fit schedule");
    assert_eq!(snap.stats.fit_incremental, twin_snap.stats.fit_incremental);
    assert_gp_params_bitwise(&snap, &twin_snap);

    // Resolve the crashed-pending trial on both, then the acceptance
    // criterion: the next ask after the restart is bitwise identical to
    // the uninterrupted hub's.
    let (tid, x) = snap.pending[0].clone();
    let y = bowl(&x);
    hub.tell(id, tid, y).unwrap();
    twin.tell(twin_id, tid, y).unwrap();
    let next_replayed = hub.ask(id, 2).unwrap();
    let next_twin = twin.ask(twin_id, 2).unwrap();
    for (a, b) in next_replayed.iter().zip(&next_twin) {
        assert_eq!(a.trial_id, b.trial_id);
        assert_eq!(a.x, b.x, "post-restart suggestion must be bitwise identical");
    }

    // And a second restart on top of the extended journal still works.
    drop(hub);
    let hub = StudyHub::open(HubConfig {
        journal: Some(path.clone()),
        pool_workers: 0,
        service: ServiceConfig::default(),
        mailbox_cap: 0,
        ..HubConfig::default()
    })
    .unwrap();
    let id = hub.find_study("serving").unwrap();
    let snap2 = hub.snapshot(id).unwrap();
    assert_eq!(
        snap2.pending.len(),
        2,
        "second replay restores the untold post-restart batch"
    );
    assert_eq!(
        snap2.pending,
        next_replayed.iter().map(|s| (s.trial_id, s.x.clone())).collect::<Vec<_>>()
    );

    let _ = std::fs::remove_file(&path);
}

/// ISSUE 8 acceptance: three-way equivalence. A hub resumed from its
/// newest snapshot record, a hub resumed by full event replay, and an
/// uninterrupted twin must agree bitwise — trials, pending set,
/// next_trial_id, fit split, warm-started GP hyperparameters — and the
/// next ask after resume must be bitwise identical across all three.
#[test]
fn snapshot_resume_equals_full_replay_equals_uninterrupted_twin() {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let path_full = dir.join(format!("dbe_bo_snapeq_full_{pid}.jsonl"));
    let path_snap = dir.join(format!("dbe_bo_snapeq_snap_{pid}.jsonl"));
    // Periodic snapshots rotate segments, so clean everything that
    // shares the journal's file-name prefix (sealed segments included).
    let rm_all = |path: &std::path::Path| {
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        if let Ok(entries) = std::fs::read_dir(path.parent().unwrap()) {
            for e in entries.flatten() {
                if e.file_name().to_string_lossy().starts_with(&name) {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }
    };
    rm_all(&path_full);
    rm_all(&path_snap);

    let spec = StudySpec::new("s", quick_cfg(2), 9);
    let hub_cfg = |path: &std::path::Path, snapshot_every: usize| HubConfig {
        journal: Some(path.to_path_buf()),
        snapshot_every,
        ..HubConfig::default()
    };

    // The uninterrupted reference.
    let twin = StudyHub::in_memory();
    let twin_id = twin.create_study(spec.clone()).unwrap();

    // Drive both journaled hubs in lockstep with the twin, then "crash"
    // (drop) with one ask still pending.
    let pending;
    {
        let full = StudyHub::open(hub_cfg(&path_full, 0)).unwrap();
        let snap = StudyHub::open(hub_cfg(&path_snap, 4)).unwrap();
        let full_id = full.create_study(spec.clone()).unwrap();
        let snap_id = snap.create_study(spec.clone()).unwrap();
        for &q in &[1usize, 1, 1, 1, 2, 1, 2] {
            let a = twin.ask(twin_id, q).unwrap();
            let b = full.ask(full_id, q).unwrap();
            let c = snap.ask(snap_id, q).unwrap();
            for ((sa, sb), sc) in a.iter().zip(&b).zip(&c) {
                assert_eq!(sa.trial_id, sb.trial_id);
                assert_eq!(sa.trial_id, sc.trial_id);
                assert_eq!(sa.x, sb.x);
                assert_eq!(sa.x, sc.x, "snapshotting hub diverged before the crash");
            }
            for s in a {
                let y = bowl(&s.x);
                twin.tell(twin_id, s.trial_id, y).unwrap();
                full.tell(full_id, s.trial_id, y).unwrap();
                snap.tell(snap_id, s.trial_id, y).unwrap();
            }
        }
        assert!(snap.journal_snapshots() > 0, "periodic snapshots must have fired");
        let a = twin.ask(twin_id, 1).unwrap();
        let b = full.ask(full_id, 1).unwrap();
        let c = snap.ask(snap_id, 1).unwrap();
        assert_eq!(a[0].x, b[0].x);
        assert_eq!(a[0].x, c[0].x);
        pending = (a[0].trial_id, a[0].x.clone());
    }

    // Reopen: one hub replays every event, the other resumes from its
    // newest snapshot record.
    let full = StudyHub::open(hub_cfg(&path_full, 0)).unwrap();
    let snap = StudyHub::open(hub_cfg(&path_snap, 4)).unwrap();
    assert!(snap.journal_snapshots() > 0, "reopen must see the snapshot records");
    let full_id = full.find_study("s").expect("full-replay hub lost the study");
    let snap_id = snap.find_study("s").expect("snapshot-resume hub lost the study");
    let t = twin.snapshot(twin_id).unwrap();
    for (label, s) in [
        ("full-replay", full.snapshot(full_id).unwrap()),
        ("snapshot-resume", snap.snapshot(snap_id).unwrap()),
    ] {
        assert_eq!(s.trials.len(), t.trials.len(), "{label}: trial count");
        for (a, b) in s.trials.iter().zip(&t.trials) {
            assert_eq!(a.x, b.x, "{label}: trial suggestion");
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "{label}: trial value");
        }
        assert_eq!(s.pending, t.pending, "{label}: pending set");
        assert_eq!(s.pending, vec![pending.clone()], "{label}: crashed pending trial");
        assert_eq!(s.next_trial_id, t.next_trial_id, "{label}: next_trial_id");
        assert_eq!(s.stats.fit_full, t.stats.fit_full, "{label}: full-fit count");
        assert_eq!(
            s.stats.fit_incremental,
            t.stats.fit_incremental,
            "{label}: incremental-fit count"
        );
        assert_gp_params_bitwise(&s, &t);
    }

    // Resolve the pending trial on all three, then the acceptance
    // criterion: the next ask after resume is bitwise identical.
    let (tid, x) = pending;
    let y = bowl(&x);
    twin.tell(twin_id, tid, y).unwrap();
    full.tell(full_id, tid, y).unwrap();
    snap.tell(snap_id, tid, y).unwrap();
    let a = twin.ask(twin_id, 2).unwrap();
    let b = full.ask(full_id, 2).unwrap();
    let c = snap.ask(snap_id, 2).unwrap();
    for ((sa, sb), sc) in a.iter().zip(&b).zip(&c) {
        assert_eq!(sa.trial_id, sb.trial_id);
        assert_eq!(sa.trial_id, sc.trial_id);
        for ((xa, xb), xc) in sa.x.iter().zip(&sb.x).zip(&sc.x) {
            assert_eq!(xa.to_bits(), xb.to_bits(), "full-replay next ask diverged");
            assert_eq!(xa.to_bits(), xc.to_bits(), "snapshot-resume next ask diverged");
        }
    }

    rm_all(&path_full);
    rm_all(&path_snap);
}

#[test]
fn multi_study_journal_keeps_tenants_separate() {
    let path = std::env::temp_dir()
        .join(format!("dbe_bo_hub_multi_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);

    {
        let hub = StudyHub::open(HubConfig {
            journal: Some(path.clone()),
            pool_workers: 0,
            service: ServiceConfig::default(),
            mailbox_cap: 0,
            ..HubConfig::default()
        })
        .unwrap();
        let a = hub.create_study(StudySpec::new("a", quick_cfg(1), 1)).unwrap();
        let b = hub.create_study(StudySpec::new("b", quick_cfg(3), 2)).unwrap();
        // Interleave the two tenants' events in the journal.
        for _ in 0..6 {
            for &(id, _name) in &[(a, "a"), (b, "b")] {
                let s = hub.ask(id, 1).unwrap().remove(0);
                hub.tell(id, s.trial_id, bowl(&s.x)).unwrap();
            }
        }
    }

    let hub = StudyHub::open(HubConfig {
        journal: Some(path.clone()),
        pool_workers: 0,
        service: ServiceConfig::default(),
        mailbox_cap: 0,
        ..HubConfig::default()
    })
    .unwrap();
    assert_eq!(hub.n_studies(), 2);
    let mut next_asks = Vec::new();
    for (name, fit_every, seed) in [("a", 1usize, 1u64), ("b", 3, 2)] {
        let id = hub.find_study(name).unwrap();
        let snap = hub.snapshot(id).unwrap();
        assert_eq!(snap.trials.len(), 6, "tenant {name} lost trials in replay");
        assert_eq!(snap.config.fit_every, fit_every);
        assert_eq!(snap.seed, seed);
        assert!(snap.pending.is_empty());
        // Ask once post-replay; the suggestion goes into the journal.
        let s = hub.ask(id, 1).unwrap().remove(0);
        assert_eq!(s.trial_id, 6);
        next_asks.push((name, (s.trial_id, s.x)));
    }
    drop(hub);

    // Replay determinism across tenants: a second reopen restores each
    // tenant's post-replay ask bitwise, as its pending trial.
    let hub = StudyHub::open(HubConfig {
        journal: Some(path.clone()),
        pool_workers: 0,
        service: ServiceConfig::default(),
        mailbox_cap: 0,
        ..HubConfig::default()
    })
    .unwrap();
    for (name, expected) in next_asks {
        let id = hub.find_study(name).unwrap();
        let snap = hub.snapshot(id).unwrap();
        assert_eq!(snap.pending, vec![expected], "tenant {name} diverged on reopen");
    }

    let _ = std::fs::remove_file(&path);
}

/// The serving tier must be numerically invisible (ISSUE 6 acceptance):
/// driving a study over real loopback TCP — JSONL frames, the raw-token
/// number codec, the bounded-mailbox path — bitwise-reproduces an
/// in-process twin, for q=1 and a q=4 fantasy batch, pool on. Three
/// layers are compared: every suggestion, the full wire snapshot, and
/// the journal bytes the two hubs wrote.
#[test]
fn tcp_loopback_bitwise_reproduces_in_process_hub() {
    for q in [1usize, 4] {
        let dir = std::env::temp_dir();
        let path_twin =
            dir.join(format!("dbe_bo_loop_twin_{}_q{q}.jsonl", std::process::id()));
        let path_wire =
            dir.join(format!("dbe_bo_loop_wire_{}_q{q}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path_twin);
        let _ = std::fs::remove_file(&path_wire);
        let hub_cfg = |path: &std::path::Path| HubConfig {
            journal: Some(path.to_path_buf()),
            pool_workers: 2,
            service: ServiceConfig::default(),
            mailbox_cap: 0,
            ..HubConfig::default()
        };
        let spec = StudySpec::new("eq", quick_cfg(2), 42);

        // In-process twin.
        let twin = StudyHub::open(hub_cfg(&path_twin)).unwrap();
        let twin_id = twin.create_study(spec.clone()).unwrap();

        // The same hub shape behind a real TCP server.
        let server = Server::bind(ServeConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            ..ServeConfig::default()
        })
        .unwrap();
        let wire_hub = Arc::new(StudyHub::open(hub_cfg(&path_wire)).unwrap());
        server.install_hub(Arc::clone(&wire_hub));
        let mut client = HubClient::connect(&server.local_addr().to_string()).unwrap();
        client.create(&spec).unwrap();

        // Lockstep: identical asks, identical tell order and values.
        let n_trials = 12;
        let mut done = 0;
        while done < n_trials {
            let k = q.min(n_trials - done);
            let a = twin.ask(twin_id, k).unwrap();
            let b = client.ask("eq", k).unwrap();
            assert_eq!(a.len(), b.len());
            for (sa, sb) in a.iter().zip(&b) {
                assert_eq!(sa.trial_id, sb.trial_id, "q={q}: trial ids diverged");
                for (xa, xb) in sa.x.iter().zip(&sb.x) {
                    assert_eq!(
                        xa.to_bits(),
                        xb.to_bits(),
                        "q={q}: TCP suggestion differs from in-process twin"
                    );
                }
            }
            for s in a {
                let y = bowl(&s.x);
                twin.tell(twin_id, s.trial_id, y).unwrap();
                client.tell("eq", s.trial_id, y).unwrap();
            }
            done += k;
        }

        // The wire snapshot is token-for-token the twin's encoding —
        // raw-token numbers make Json equality bitwise f64 equality.
        let wire_snap = client.snapshot("eq").unwrap();
        let twin_snap = snapshot_to_json(&twin.snapshot(twin_id).unwrap());
        assert_eq!(wire_snap, twin_snap, "q={q}: wire snapshot diverged");

        // Drain the server through the protocol, then compare journals.
        client.shutdown().unwrap();
        drop(client);
        server.join();
        drop(wire_hub);
        drop(twin);
        let bytes_twin = std::fs::read(&path_twin).unwrap();
        let bytes_wire = std::fs::read(&path_wire).unwrap();
        assert!(!bytes_twin.is_empty());
        assert_eq!(
            bytes_twin, bytes_wire,
            "q={q}: TCP-driven journal must be byte-identical to the twin's"
        );

        let _ = std::fs::remove_file(&path_twin);
        let _ = std::fs::remove_file(&path_wire);
    }
}
