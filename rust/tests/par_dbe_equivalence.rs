//! Par-D-BE cross-strategy equivalence and BatchService coalescing
//! under multi-client load.
//!
//! The paper's guarantee — decoupled QN states make trajectories
//! independent of how evaluations are batched — extends to sharding:
//! Par-D-BE must reproduce D-BE (and hence SEQ. OPT.) per restart, for
//! any worker count, whether the shards evaluate in-process or through
//! the coalescing service.

use dbe_bo::batcheval::{BatchAcqEvaluator, SyntheticEvaluator};
use dbe_bo::bbob::{Objective, Rosenbrock};
use dbe_bo::coordinator::{BatchService, ServiceConfig};
use dbe_bo::optim::lbfgsb::LbfgsbOptions;
use dbe_bo::optim::mso::{run_mso, MsoConfig, MsoStrategy, ParDbe};
use dbe_bo::rng::Pcg64;
use std::sync::{Arc, Barrier};
use std::time::Duration;

fn rosen_eval(d: usize) -> SyntheticEvaluator {
    SyntheticEvaluator::new(Box::new(Rosenbrock::new(d)))
}

fn starts(b: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seeded(seed);
    (0..b).map(|_| rng.uniform_vec(d, 0.0, 3.0)).collect()
}

fn cfg(d: usize) -> MsoConfig {
    MsoConfig { bounds: vec![(0.0, 3.0); d], lbfgsb: LbfgsbOptions::default() }
}

#[test]
fn par_dbe_matches_dbe_and_seq_per_restart() {
    // The acceptance equivalence: same x0s, same oracle ⇒ bitwise
    // identical per-restart results across SEQ / D-BE / Par-D-BE.
    let d = 5;
    let ev = rosen_eval(d);
    let x0s = starts(8, d, 71);
    let c = cfg(d);
    let seq = run_mso(MsoStrategy::SeqOpt, &ev, &x0s, &c).unwrap();
    let dbe = run_mso(MsoStrategy::Dbe, &ev, &x0s, &c).unwrap();
    for workers in [1, 2, 4, 8] {
        let par = ParDbe::with_workers(workers).run(&ev, &x0s, &c).unwrap();
        assert_eq!(par.restarts.len(), 8);
        for ((s, d_), p) in seq.restarts.iter().zip(&dbe.restarts).zip(&par.restarts) {
            assert_eq!(s.x, p.x, "workers={workers}: Par-D-BE must replay SEQ");
            assert_eq!(d_.x, p.x);
            assert_eq!(s.f, p.f);
            assert_eq!(s.iters, p.iters);
            assert_eq!(s.reason, p.reason);
        }
    }
}

#[test]
fn par_dbe_through_service_matches_direct_run() {
    // Shards submitting through the coalescing worker must see exactly
    // the same oracle answers as a direct in-process run — for every
    // worker count (different shardings hit different coalescing
    // boundaries).
    let d = 4;
    let ev = rosen_eval(d);
    let x0s = starts(6, d, 73);
    let c = cfg(d);
    let direct = ParDbe::with_workers(1).run(&ev, &x0s, &c).unwrap();

    let (svc, handle) = BatchService::spawn(
        Box::new(rosen_eval(d)),
        ServiceConfig { max_batch: 32, max_wait: Duration::from_micros(300) },
    );
    let mut points_through_service = 0usize;
    for workers in [1, 2, 4, 8] {
        let via_service = ParDbe::with_workers(workers).run(&svc, &x0s, &c).unwrap();
        for (a, b) in direct.restarts.iter().zip(&via_service.restarts) {
            assert_eq!(a.x, b.x, "workers={workers}: coalescing must not perturb trajectories");
            assert_eq!(a.f, b.f);
            assert_eq!(a.iters, b.iters);
        }
        assert_eq!(via_service.n_points, direct.n_points, "workers={workers}");
        points_through_service += via_service.n_points;
    }
    // The worker never drops or duplicates a point across all runs.
    assert_eq!(svc.metrics.snapshot().points as usize, points_through_service);
    drop(svc);
    handle.join().unwrap();
}

#[test]
fn service_coalesces_under_multi_client_load() {
    // A deliberately slow oracle + barrier-released clients: while the
    // worker is inside one oracle call, the other clients' requests
    // queue up and MUST be coalesced into the next call.
    struct SlowEval {
        inner: SyntheticEvaluator,
    }
    impl BatchAcqEvaluator for SlowEval {
        fn dim(&self) -> usize {
            self.inner.dim()
        }
        fn eval_batch(&self, xs: &[Vec<f64>]) -> dbe_bo::Result<(Vec<f64>, Vec<Vec<f64>>)> {
            std::thread::sleep(Duration::from_millis(5));
            self.inner.eval_batch(xs)
        }
    }

    let n_clients = 8;
    let rounds = 10;
    let (svc, handle) = BatchService::spawn(
        Box::new(SlowEval { inner: rosen_eval(2) }),
        ServiceConfig { max_batch: 64, max_wait: Duration::from_millis(1) },
    );
    let barrier = Arc::new(Barrier::new(n_clients));
    let mut joins = Vec::new();
    for t in 0..n_clients {
        let svc = svc.clone();
        let barrier = Arc::clone(&barrier);
        joins.push(std::thread::spawn(move || {
            barrier.wait();
            let f = Rosenbrock::new(2);
            for i in 0..rounds {
                let p = vec![0.2 + 0.01 * t as f64, 0.3 + 0.01 * i as f64];
                let (vals, _) = svc.eval(vec![p.clone()]).unwrap();
                assert_eq!(vals[0], f.value(&p), "client {t} round {i}: wrong value");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.points as usize, n_clients * rounds, "no point dropped or duplicated");
    assert!(
        (snap.batches as usize) < n_clients * rounds,
        "coalescing must merge concurrent submissions: {snap}"
    );
    assert!(svc.metrics.mean_batch_size() > 1.0);
    drop(svc);
    handle.join().unwrap();
}

#[test]
fn par_dbe_shard_stats_are_consistent_with_totals() {
    let d = 3;
    let ev = rosen_eval(d);
    let x0s = starts(9, d, 77);
    let res = ParDbe::with_workers(4).run(&ev, &x0s, &cfg(d)).unwrap();
    assert_eq!(res.shards.len(), 4);
    assert_eq!(res.shards.iter().map(|s| s.restarts).sum::<usize>(), 9);
    assert_eq!(res.shards.iter().map(|s| s.batches).sum::<usize>(), res.n_batches);
    assert_eq!(res.shards.iter().map(|s| s.points).sum::<usize>(), res.n_points);
    // Active-set pruning survives sharding: with default tolerances
    // every restart converges, so total points < batches × B.
    assert!(res.n_points <= res.n_batches * 9);
}
