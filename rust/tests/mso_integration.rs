//! Cross-module integration: MSO strategies over the *real* GP
//! acquisition (not synthetic functions) — the paper's actual setting.

use dbe_bo::batcheval::{CountingEvaluator, NativeGpEvaluator};
use dbe_bo::bo::{Study, StudyConfig};
use dbe_bo::gp::{GpParams, GpRegressor};
use dbe_bo::optim::lbfgsb::LbfgsbOptions;
use dbe_bo::optim::mso::{run_mso, MsoConfig, MsoStrategy};
use dbe_bo::rng::Pcg64;

fn fitted_gp(n: usize, d: usize, seed: u64) -> GpRegressor {
    let mut rng = Pcg64::seeded(seed);
    let x: Vec<Vec<f64>> = (0..n).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
    let y: Vec<f64> = x
        .iter()
        .map(|p| {
            p.iter().enumerate().map(|(i, v)| (v - 0.3 - 0.1 * i as f64).powi(2)).sum::<f64>()
        })
        .collect();
    GpRegressor::fit(x, &y, GpParams::default()).unwrap()
}

#[test]
fn dbe_replays_seq_on_gp_acquisition() {
    // The headline equivalence on the real acquisition surface.
    let gp = fitted_gp(40, 3, 1);
    let ev = NativeGpEvaluator::new(&gp);
    let mut rng = Pcg64::seeded(2);
    let x0s: Vec<Vec<f64>> = (0..10).map(|_| rng.uniform_vec(3, 0.0, 1.0)).collect();
    let cfg = MsoConfig {
        bounds: vec![(0.0, 1.0); 3],
        lbfgsb: LbfgsbOptions { pgtol: 1e-2, max_iters: 200, ftol: 0.0, ..Default::default() },
    };
    let seq = run_mso(MsoStrategy::SeqOpt, &ev, &x0s, &cfg).unwrap();
    let dbe = run_mso(MsoStrategy::Dbe, &ev, &x0s, &cfg).unwrap();
    for (a, b) in seq.restarts.iter().zip(&dbe.restarts) {
        assert_eq!(a.x, b.x);
        assert_eq!(a.f, b.f);
        assert_eq!(a.iters, b.iters);
    }
    // And batching really reduced oracle calls.
    assert!(dbe.n_batches < seq.n_batches);
}

#[test]
fn evaluation_counts_ordering() {
    // SEQ: n_batches == n_points. D-BE: fewer batches, same-ish points.
    // C-BE: every batch carries all B points.
    let gp = fitted_gp(30, 2, 3);
    let ev = CountingEvaluator::new(NativeGpEvaluator::new(&gp));
    let mut rng = Pcg64::seeded(5);
    let b = 8;
    let x0s: Vec<Vec<f64>> = (0..b).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
    let cfg = MsoConfig {
        bounds: vec![(0.0, 1.0); 2],
        lbfgsb: LbfgsbOptions { pgtol: 1e-2, max_iters: 200, ftol: 0.0, ..Default::default() },
    };
    let seq = run_mso(MsoStrategy::SeqOpt, &ev, &x0s, &cfg).unwrap();
    assert_eq!(seq.n_batches, seq.n_points);

    let cbe = run_mso(MsoStrategy::Cbe, &ev, &x0s, &cfg).unwrap();
    assert_eq!(cbe.n_points, cbe.n_batches * b);

    let dbe = run_mso(MsoStrategy::Dbe, &ev, &x0s, &cfg).unwrap();
    assert!(dbe.n_batches <= seq.n_points);
    assert!(dbe.n_batches < dbe.n_points);
}

#[test]
fn full_bo_studies_reach_comparable_quality() {
    // The Table-1 "Best Value comparable across methods" claim, shrunk.
    let objective = |x: &[f64]| {
        x.iter().map(|v| v * v).sum::<f64>() + (3.0 * x[0]).sin() * 0.5
    };
    let mut bests = Vec::new();
    for strategy in MsoStrategy::all() {
        let cfg = StudyConfig {
            dim: 2,
            bounds: vec![(-3.0, 3.0); 2],
            n_trials: 22,
            n_startup: 8,
            restarts: 6,
            strategy,
            ..StudyConfig::default()
        };
        let mut study = Study::new(cfg, 77);
        let best = study.optimize(objective);
        bests.push(best.value);
    }
    let spread = bests.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - bests.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(
        spread < 1.0,
        "strategies should reach comparable quality, got {bests:?}"
    );
}

#[test]
fn cbe_iteration_inflation_on_gp_acquisition() {
    // §5: C-BE's iteration count inflates on the real acquisition too.
    // Use tight tolerances so iteration counts measure convergence.
    let gp = fitted_gp(50, 5, 7);
    let ev = NativeGpEvaluator::new(&gp);
    let mut rng = Pcg64::seeded(8);
    let x0s: Vec<Vec<f64>> = (0..10).map(|_| rng.uniform_vec(5, 0.0, 1.0)).collect();
    let cfg = MsoConfig {
        bounds: vec![(0.0, 1.0); 5],
        lbfgsb: LbfgsbOptions { pgtol: 1e-5, ftol: 0.0, max_iters: 300, ..Default::default() },
    };
    let seq = run_mso(MsoStrategy::SeqOpt, &ev, &x0s, &cfg).unwrap();
    let cbe = run_mso(MsoStrategy::Cbe, &ev, &x0s, &cfg).unwrap();
    assert!(
        cbe.median_iters() >= seq.median_iters(),
        "C-BE {} vs SEQ {}",
        cbe.median_iters(),
        seq.median_iters()
    );
}

#[test]
fn study_stats_are_internally_consistent() {
    let cfg = StudyConfig {
        dim: 2,
        bounds: vec![(-2.0, 2.0); 2],
        n_trials: 16,
        n_startup: 6,
        restarts: 5,
        strategy: MsoStrategy::Dbe,
        ..StudyConfig::default()
    };
    let mut study = Study::new(cfg, 3);
    study.optimize(|x| x[0] * x[0] + x[1] * x[1]);
    let s = &study.stats;
    assert_eq!(s.iters.len(), (16 - 6) * 5);
    assert!(s.n_points >= s.n_batches);
    assert!(s.acq_wall <= s.total_wall);
}
