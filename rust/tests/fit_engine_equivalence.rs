//! Fit-engine equivalence gate (ISSUE 2 acceptance):
//!
//! 1. the cached-distance MLL path is numerically indistinguishable
//!    from the frozen pre-engine reference (`gp::naive`) — values
//!    bitwise, gradients ≤ 1e-12 on the seed's `toy_data` fixtures;
//! 2. `refit_append` matches a from-scratch `with_params` to ≤ 1e-12
//!    in α, posterior mean/var and their input-gradients (property-
//!    tested over random sets via `testing::forall`);
//! 3. no dense `CholeskyFactor::inverse()` call remains on the
//!    MLL-evaluation or posterior hot path (grep-enforced on the gp
//!    hot-path sources).

use dbe_bo::gp::{mll_value_grad, naive, GpParams, GpRegressor, Standardizer};
use dbe_bo::rng::Pcg64;
use dbe_bo::testing::forall;

/// The seed's `toy_data` fixture, reproduced verbatim from
/// `rust/src/gp/regressor.rs` tests.
fn toy_data(n: usize, d: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Pcg64::seeded(seed);
    let x: Vec<Vec<f64>> = (0..n).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
    let y: Vec<f64> =
        x.iter().map(|p| (6.0 * p[0]).sin() + p.iter().sum::<f64>() * 0.5).collect();
    (x, y)
}

fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} vs {b} (|diff| {}, tol {tol})", (a - b).abs()))
    }
}

fn allclose(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        close(*x, *y, tol).map_err(|e| format!("index {i}: {e}"))?;
    }
    Ok(())
}

#[test]
fn cached_mll_matches_naive_exactly_on_toy_fixtures() {
    // Every (n, d, seed) fixture the seed's regressor tests use, plus a
    // spread of hyperparameters including short lengthscales (AR cutoff
    // active) and the default prior.
    let fixtures = [(12usize, 2usize, 3u64), (20, 2, 1), (25, 2, 4), (15, 3, 5), (30, 2, 7)];
    let params = [
        GpParams::default(),
        GpParams { log_len: (0.4f64).ln(), log_sf2: (0.8f64).ln(), log_noise: (1e-3f64).ln() },
        GpParams { log_len: (0.02f64).ln(), log_sf2: (2.0f64).ln(), log_noise: (1e-4f64).ln() },
        GpParams { log_len: (3.0f64).ln(), log_sf2: (0.1f64).ln(), log_noise: (0.3f64).ln() },
    ];
    for &(n, d, seed) in &fixtures {
        let (x, y) = toy_data(n, d, seed);
        let y_std = Standardizer::fit(&y).forward_vec(&y);
        for p in &params {
            let (v_naive, g_naive) = naive::mll_value_grad_naive(&x, &y_std, p).unwrap();
            let (v_cached, g_cached) = mll_value_grad(&x, &y_std, p).unwrap();
            assert!(
                v_cached == v_naive,
                "MLL value must be bitwise identical (n={n} d={d} seed={seed}): {v_cached} vs {v_naive}"
            );
            allclose(&g_cached, &g_naive, 1e-12)
                .unwrap_or_else(|e| panic!("gradient drift (n={n} d={d} seed={seed}): {e}"));
        }
    }
}

#[test]
fn cached_mll_matches_naive_on_random_problems() {
    forall("cached MLL ≈ naive MLL", 30, |g| {
        let n = 3 + g.size(20);
        let d = 1 + g.rng.below(5);
        let x: Vec<Vec<f64>> = (0..n).map(|_| g.rng.uniform_vec(d, 0.0, 1.0)).collect();
        let y: Vec<f64> = x
            .iter()
            .map(|p| (4.0 * p[0]).sin() + p.iter().sum::<f64>() + 0.1 * g.rng.normal())
            .collect();
        let y_std = Standardizer::fit(&y).forward_vec(&y);
        // Noise floored at 1e-4 keeps the kernel well-conditioned so the
        // comparison tests the algebra, not jitter-retry edge cases.
        let params = GpParams {
            log_len: g.rng.uniform_in((0.05f64).ln(), (2.0f64).ln()),
            log_sf2: g.rng.uniform_in(-1.0, 1.0),
            log_noise: g.rng.uniform_in((1e-4f64).ln(), (1e-1f64).ln()),
        };
        let (v_naive, g_naive) =
            naive::mll_value_grad_naive(&x, &y_std, &params).map_err(|e| e.to_string())?;
        let (v_cached, g_cached) =
            mll_value_grad(&x, &y_std, &params).map_err(|e| e.to_string())?;
        if v_cached != v_naive {
            return Err(format!("value drift: {v_cached} vs {v_naive}"));
        }
        allclose(&g_cached, &g_naive, 1e-10)
    });
}

#[test]
fn refit_append_matches_from_scratch_property() {
    forall("refit_append ≡ with_params", 25, |g| {
        let n = 4 + g.size(16);
        let d = 1 + g.rng.below(4);
        let extra = 1 + g.rng.below(3);
        let total = n + extra;
        let x: Vec<Vec<f64>> = (0..total).map(|_| g.rng.uniform_vec(d, 0.0, 1.0)).collect();
        let y: Vec<f64> = x
            .iter()
            .map(|p| (3.0 * p[0]).cos() + p.iter().map(|v| v * v).sum::<f64>())
            .collect();
        let params = GpParams {
            log_len: g.rng.uniform_in((0.1f64).ln(), (1.5f64).ln()),
            log_sf2: g.rng.uniform_in(-0.7, 0.7),
            log_noise: g.rng.uniform_in((1e-5f64).ln(), (1e-1f64).ln()),
        };

        let mut inc = GpRegressor::with_params(x[..n].to_vec(), &y[..n], params)
            .map_err(|e| e.to_string())?;
        for i in n..total {
            inc.refit_append(x[i].clone(), y[i]).map_err(|e| e.to_string())?;
        }
        let full =
            GpRegressor::with_params(x.clone(), &y, params).map_err(|e| e.to_string())?;

        allclose(inc.alpha(), full.alpha(), 1e-12).map_err(|e| format!("alpha: {e}"))?;
        close(inc.best_y_std(), full.best_y_std(), 1e-15)
            .map_err(|e| format!("incumbent: {e}"))?;

        // Posterior mean/var/gradients at random queries AND at the
        // appended training points (worst-case cancellation).
        let mut queries: Vec<Vec<f64>> =
            (0..4).map(|_| g.rng.uniform_vec(d, 0.0, 1.0)).collect();
        queries.extend(x[n..].iter().cloned());
        for q in &queries {
            let a = inc.posterior(q);
            let b = full.posterior(q);
            close(a.mean, b.mean, 1e-12).map_err(|e| format!("mean@{q:?}: {e}"))?;
            close(a.var, b.var, 1e-12).map_err(|e| format!("var@{q:?}: {e}"))?;
            allclose(&a.dmean, &b.dmean, 1e-12).map_err(|e| format!("dmean@{q:?}: {e}"))?;
            allclose(&a.dvar, &b.dvar, 1e-12).map_err(|e| format!("dvar@{q:?}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn refit_append_then_full_fit_warm_start_stays_consistent() {
    // A BO-shaped interleaving: append a few points incrementally, then
    // verify a full fit on the grown set still succeeds and improves
    // (or matches) the held-hyperparameter MLL.
    let (x, y) = toy_data(18, 2, 9);
    let params = GpParams::default();
    let mut gp = GpRegressor::with_params(x[..12].to_vec(), &y[..12], params).unwrap();
    for i in 12..18 {
        gp.refit_append(x[i].clone(), y[i]).unwrap();
    }
    let y_std = Standardizer::fit(&y).forward_vec(&y);
    let (mll_held, _) = mll_value_grad(&x, &y_std, &gp.params).unwrap();
    let refit = GpRegressor::fit(x.clone(), &y, gp.params).unwrap();
    let (mll_refit, _) = mll_value_grad(&x, &y_std, &refit.params).unwrap();
    assert!(
        mll_refit >= mll_held - 1e-9,
        "full refit regressed the MLL: {mll_refit} < {mll_held}"
    );
}

/// Dense symmetric solve by Gaussian elimination with partial
/// pivoting — deliberately naive, so the brute-force LOO below shares
/// no code path with the factor-cache identities under test.
fn solve_dense(a: &[Vec<f64>], b: &[f64]) -> Vec<f64> {
    let n = b.len();
    let mut m: Vec<Vec<f64>> = a.iter().map(|r| r.clone()).collect();
    let mut rhs = b.to_vec();
    for col in 0..n {
        let piv = (col..n)
            .max_by(|&i, &j| m[i][col].abs().total_cmp(&m[j][col].abs()))
            .unwrap();
        m.swap(col, piv);
        rhs.swap(col, piv);
        let d = m[col][col];
        assert!(d.abs() > 1e-300, "brute-force solve hit a singular pivot");
        for row in col + 1..n {
            let f = m[row][col] / d;
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                m[row][k] -= f * m[col][k];
            }
            rhs[row] -= f * rhs[col];
        }
    }
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut s = rhs[row];
        for k in row + 1..n {
            s -= m[row][k] * x[k];
        }
        x[row] = s / m[row][row];
    }
    x
}

/// ISSUE 10 acceptance: `loo_diagnostics()` — the O(n²) identities off
/// the cached factors (`residual_i = α_i/[K⁻¹]_ii`, `σ²_{−i} =
/// 1/[K⁻¹]_ii`) — must match brute-force leave-one-out at ≤ 1e-10.
/// Brute force here means independent linear algebra: reconstruct the
/// noisy covariance `K_n = L·Lᵀ` from the regressor's own factor, then
/// for every held-out point solve the (n−1)-point system from scratch
/// with dense elimination, all in the FULL model's standardized frame
/// (fixed hyperparameters, fixed standardizer — LOO at fixed params is
/// not a re-fit).
#[test]
fn loo_diagnostics_match_brute_force_holdout() {
    let params = [
        GpParams::default(),
        GpParams { log_len: (0.4f64).ln(), log_sf2: (0.8f64).ln(), log_noise: (1e-3f64).ln() },
        GpParams { log_len: (2.0f64).ln(), log_sf2: (0.2f64).ln(), log_noise: (0.1f64).ln() },
    ];
    for &(n, d, seed) in &[(14usize, 2usize, 3u64), (20, 3, 5)] {
        let (x, y) = toy_data(n, d, seed);
        for p in &params {
            let gp = GpRegressor::with_params(x.clone(), &y, *p).unwrap();
            let diag = gp.loo_diagnostics();
            assert_eq!(diag.residuals.len(), n);
            assert_eq!(diag.variances.len(), n);

            // K_n = L·Lᵀ (noise included — LOO predicts the noisy target).
            let l = gp.chol_l();
            let kn: Vec<Vec<f64>> = (0..n)
                .map(|i| {
                    (0..n)
                        .map(|j| {
                            l.row(i).iter().zip(l.row(j)).map(|(a, b)| a * b).sum()
                        })
                        .collect()
                })
                .collect();
            let y_std = gp.train_y_std();

            for i in 0..n {
                let keep: Vec<usize> = (0..n).filter(|&j| j != i).collect();
                let sub: Vec<Vec<f64>> = keep
                    .iter()
                    .map(|&r| keep.iter().map(|&c| kn[r][c]).collect())
                    .collect();
                let y_sub: Vec<f64> = keep.iter().map(|&j| y_std[j]).collect();
                let k_i: Vec<f64> = keep.iter().map(|&j| kn[i][j]).collect();
                let w_y = solve_dense(&sub, &y_sub);
                let w_k = solve_dense(&sub, &k_i);
                let mu = k_i.iter().zip(&w_y).map(|(a, b)| a * b).sum::<f64>();
                let var = kn[i][i]
                    - k_i.iter().zip(&w_k).map(|(a, b)| a * b).sum::<f64>();
                close(diag.residuals[i], y_std[i] - mu, 1e-10).unwrap_or_else(|e| {
                    panic!("LOO residual {i} (n={n} seed={seed}): {e}")
                });
                close(diag.variances[i], var, 1e-10).unwrap_or_else(|e| {
                    panic!("LOO variance {i} (n={n} seed={seed}): {e}")
                });
            }
        }
    }
}

/// ISSUE 10 grep lint (mirrors `no_dense_inverse_on_hot_paths`): the
/// health engine must derive every diagnostic from factors the
/// regressor already caches. A factorization, dense solve, inverse, or
/// GP re-fit inside `obs/health.rs` would turn an O(n²) observer into
/// an O(n³) tax on the tell path. CI's health-smoke job runs the same
/// grep.
#[test]
fn health_engine_never_factorizes_or_refits() {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src/obs/health.rs");
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("cannot read obs/health.rs: {e}"));
    let lower = src.to_lowercase();
    for needle in ["cholesky", "solve", "inverse", "with_params"] {
        assert!(
            !lower.contains(needle),
            "obs/health.rs mentions '{needle}' — health must consume \
             LooDiagnostics/AskQuality computed from cached factors, \
             never run its own linear algebra or fits"
        );
    }
    // And the O(n²) identity source must exist where health expects it.
    let reg = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/src/gp/regressor.rs");
    let reg_src = std::fs::read_to_string(&reg).unwrap();
    assert!(
        reg_src.contains("pub fn loo_diagnostics"),
        "gp/regressor.rs no longer exposes loo_diagnostics; update the \
         health engine wiring"
    );
}

/// Grep-enforced acceptance criterion: the MLL-evaluation and posterior
/// hot paths must not materialize a dense inverse. `gp/naive.rs` (the
/// frozen reference) and `runtime/evaluator.rs` (once-per-fit artifact
/// assembly) are the only sanctioned `.inverse()` consumers in the GP
/// stack.
#[test]
fn no_dense_inverse_on_hot_paths() {
    let hot_paths =
        ["rust/src/gp/regressor.rs", "rust/src/gp/fit.rs", "rust/src/gp/acquisition.rs"];
    for rel in hot_paths {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(rel);
        let src = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("cannot read {rel}: {e}"));
        assert!(
            !src.contains(".inverse()"),
            "{rel} calls a dense .inverse() — the fit engine must use \
             solve_rows_in_place / inv_lower_transpose instead"
        );
    }
}
