//! Property/fuzz battery for the hub's JSONL codec (`hub::json`).
//!
//! The codec is network-facing (journal records and `dbe-bo serve`
//! frames), so two things must hold unconditionally:
//!
//! 1. **Round-trip fidelity** — any tree the emitter can produce parses
//!    back structurally equal. Numbers are raw tokens, so structural
//!    equality is token equality, which is bitwise f64/u64 equality.
//! 2. **Total parsing** — arbitrary malformed input returns `Err`;
//!    it never panics and never overflows the stack (depth cap).
//!
//! Random trees come from the in-crate `forall` runner (seeded Pcg64,
//! scale-shrinking), so failures replay exactly.
//!
//! The battery also covers the journal built on this codec (ISSUE 8
//! satellite): `Journal::open` and `Journal::read_all` share one strict
//! decoder, so over mutated journal byte streams the two recovery paths
//! must reach the same verdict.

use dbe_bo::bo::StudyConfig;
use dbe_bo::hub::json::{Json, MAX_DEPTH};
use dbe_bo::hub::{HubConfig, Journal, JournalEvent, StudyHub, StudySpec, SyncPolicy};
use dbe_bo::optim::mso::MsoStrategy;
use dbe_bo::testing::{forall, Gen};

/// Characters that exercise every escape path in the emitter: quoting,
/// backslash, the named escapes, a sub-0x20 control (emitted as \u), a
/// multi-byte scalar, an astral-plane scalar, and JSON structure bytes
/// that must pass through strings unharmed.
const STRING_ALPHABET: &[char] = &[
    'a', 'Z', '0', ' ', '"', '\\', '/', '\n', '\r', '\t', '\u{1}', '\u{1f}', 'é', 'λ',
    '🦀', '{', '}', '[', ']', ':', ',',
];

fn gen_string(g: &mut Gen) -> String {
    let len = g.rng.below(9);
    (0..len).map(|_| STRING_ALPHABET[g.rng.below(STRING_ALPHABET.len())]).collect()
}

fn gen_finite_f64(g: &mut Gen) -> f64 {
    loop {
        let v = f64::from_bits(g.rng.next_u64());
        if v.is_finite() {
            return v;
        }
    }
}

fn gen_num(g: &mut Gen) -> Json {
    match g.rng.below(3) {
        0 => Json::u64(g.rng.next_u64()),
        1 => Json::f64(gen_finite_f64(g)),
        _ => Json::f64(g.f64_in(1e9)),
    }
}

/// Random Json tree; `depth` bounds nesting (leaf-only at 0).
fn gen_value(g: &mut Gen, depth: usize) -> Json {
    let n_kinds = if depth == 0 { 4 } else { 6 };
    match g.rng.below(n_kinds) {
        0 => Json::Null,
        1 => Json::Bool(g.rng.below(2) == 0),
        2 => gen_num(g),
        3 => Json::Str(gen_string(g)),
        4 => {
            let n = g.rng.below(5);
            Json::Arr((0..n).map(|_| gen_value(g, depth - 1)).collect())
        }
        _ => {
            let n = g.rng.below(5);
            Json::Obj(
                (0..n).map(|_| (gen_string(g), gen_value(g, depth - 1))).collect(),
            )
        }
    }
}

#[test]
fn random_trees_round_trip_structurally() {
    forall("emit→parse round-trips the tree", 300, |g| {
        let depth = g.size(6);
        let tree = gen_value(g, depth);
        let text = tree.to_string();
        let back = Json::parse(&text)
            .map_err(|e| format!("emitted {text:?} failed to parse: {e}"))?;
        // PartialEq on Json compares Num tokens verbatim, so this is
        // bitwise number equality, not approximate equality.
        if back != tree {
            return Err(format!("round-trip changed the tree: {text:?}"));
        }
        Ok(())
    });
}

#[test]
fn random_finite_f64_round_trip_bitwise() {
    forall("f64 bits survive emit→parse", 2000, |g| {
        let v = gen_finite_f64(g);
        let back = Json::parse(&Json::f64(v).to_string())
            .map_err(|e| format!("{v:?}: {e}"))?
            .as_f64()
            .map_err(|e| format!("{v:?}: {e}"))?;
        if back.to_bits() != v.to_bits() {
            return Err(format!("{v:?} ({:#x}) came back {back:?}", v.to_bits()));
        }
        Ok(())
    });
}

#[test]
fn random_u64_round_trip_exact() {
    forall("u64 survives emit→parse", 2000, |g| {
        let v = g.rng.next_u64();
        let back = Json::parse(&Json::u64(v).to_string())
            .map_err(|e| format!("{v}: {e}"))?
            .as_u64()
            .map_err(|e| format!("{v}: {e}"))?;
        if back != v {
            return Err(format!("{v} came back {back}"));
        }
        Ok(())
    });
}

#[test]
fn negative_zero_is_preserved() {
    let back = Json::parse(&Json::f64(-0.0).to_string()).unwrap().as_f64().unwrap();
    assert_eq!(back.to_bits(), (-0.0f64).to_bits(), "-0.0 must not collapse to 0.0");
}

/// Every entry must return `Err` from `Json::parse` — and, implicitly,
/// not panic. Covers truncation, bad escapes, the strict number
/// grammar (Rust's `f64::from_str` would accept several of these),
/// bad literals, structural junk, and trailing garbage.
#[test]
fn malformed_corpus_errors_without_panicking() {
    let corpus: &[&str] = &[
        "",
        "   ",
        "{",
        "[1,2",
        "{\"a\":1",
        "\"abc",
        "\"\\u12",
        "\"\\q\"",
        "+1",
        "01",
        "1.",
        ".5",
        "--3",
        "1e",
        "1e+",
        "-",
        "{\"a\":+2}",
        "[01]",
        "nul",
        "truee",
        "falsely",
        "[1 2]",
        "{\"a\" 1}",
        "{\"a\":1,}",
        "[1,]",
        "{,}",
        "{\"a\":1} x",
        "[] []",
        "\"\\u{41}\"",
        "{\"a\"}",
        "[\"\\uD800\"]",
    ];
    for src in corpus {
        assert!(
            Json::parse(src).is_err(),
            "malformed input {src:?} must be rejected"
        );
    }
}

fn nested_arrays(n: usize) -> String {
    let mut s = String::with_capacity(2 * n + 4);
    for _ in 0..n {
        s.push('[');
    }
    s.push_str("null");
    for _ in 0..n {
        s.push(']');
    }
    s
}

#[test]
fn depth_cap_boundary_is_exact() {
    // A scalar under n arrays parses at depth MAX_DEPTH - n, so
    // n = MAX_DEPTH - 1 is the deepest accepted nesting.
    assert!(Json::parse(&nested_arrays(MAX_DEPTH - 1)).is_ok());
    assert!(Json::parse(&nested_arrays(MAX_DEPTH)).is_err());
    assert!(Json::parse(&nested_arrays(MAX_DEPTH + 1)).is_err());
}

#[test]
fn deep_nesting_bomb_errors_fast_instead_of_overflowing() {
    // 100k unclosed brackets: without the depth cap this would recurse
    // 100k frames deep and blow the stack before ever reporting EOF.
    let bomb = "[".repeat(100_000);
    assert!(Json::parse(&bomb).is_err());
    let obj_bomb = "{\"k\":".repeat(100_000);
    assert!(Json::parse(&obj_bomb).is_err());
}

/// Satellite bugfix (ISSUE 8): `Journal::open` and `Journal::read_all`
/// route through one shared strict decoder, so over arbitrarily
/// mutated journal byte streams the two recovery paths must reach the
/// same verdict — both replay the identical event list, or both reject
/// the stream. (The historical bug: `read_all` silently skipped empty
/// terminated lines that `open` hard-errors on, so a supervisor
/// rebuild could diverge from a process restart on the same file.)
#[test]
fn journal_open_and_read_all_verdicts_agree_on_mutated_streams() {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let base_path = dir.join(format!("dbe_bo_jprop_base_{pid}.jsonl"));
    let open_path = dir.join(format!("dbe_bo_jprop_open_{pid}.jsonl"));
    let ra_path = dir.join(format!("dbe_bo_jprop_ra_{pid}.jsonl"));
    for p in [&base_path, &open_path, &ra_path] {
        let _ = std::fs::remove_file(p);
    }

    // A realistic base stream — format header, create, asks/tells, one
    // snapshot record — produced by the real hub, not handcrafted.
    {
        let hub = StudyHub::open(HubConfig {
            journal: Some(base_path.clone()),
            ..HubConfig::default()
        })
        .unwrap();
        let cfg = StudyConfig {
            dim: 2,
            bounds: vec![(-5.0, 5.0); 2],
            n_trials: 40,
            n_startup: 4,
            restarts: 3,
            strategy: MsoStrategy::Dbe,
            fit_every: 2,
            ..StudyConfig::default()
        };
        let id = hub.create_study(StudySpec::new("s", cfg, 5)).unwrap();
        for _ in 0..5 {
            let s = hub.ask(id, 1).unwrap().remove(0);
            let y = (s.x[0] - 0.5).powi(2) + (s.x[1] + 1.0).powi(2);
            hub.tell(id, s.trial_id, y).unwrap();
        }
        hub.checkpoint(id).unwrap();
    }
    let base = std::fs::read(&base_path).unwrap();
    assert!(base.is_ascii(), "journal lines are ASCII, so mutations stay UTF-8-safe");

    // A live handle whose recorded valid prefix outsizes every mutant:
    // swapping the file's bytes underneath it makes `read_all` decode
    // exactly the mutant (its `take(valid_len)` caps at EOF), the same
    // bytes `open` sees from a cold start.
    let (mut padded, _) = Journal::open(&ra_path, SyncPolicy::Os).unwrap();
    while std::fs::metadata(&ra_path).unwrap().len() <= (base.len() + 64) as u64 {
        padded
            .append(&JournalEvent::Tell { study: 0, trial_id: 0, value: 1.0 })
            .unwrap();
    }

    forall("open ≡ read_all over mutated journal streams", 200, |g| {
        let mut bytes = base.clone();
        for _ in 0..=g.rng.below(3) {
            if bytes.is_empty() {
                break;
            }
            let at = g.rng.below(bytes.len());
            match g.rng.below(5) {
                0 => bytes[at] = (32 + g.rng.below(95)) as u8,
                1 => {
                    bytes.remove(at);
                }
                2 => bytes.insert(at, b"{}[]\",:\n "[g.rng.below(9)]),
                3 => bytes.truncate(at),
                _ => {
                    // Blank the line containing `at`, keeping its
                    // terminator — the empty-terminated-line shape the
                    // historical read_all skipped and open rejected.
                    let start = bytes[..at]
                        .iter()
                        .rposition(|&b| b == b'\n')
                        .map_or(0, |p| p + 1);
                    let end = bytes[at..]
                        .iter()
                        .position(|&b| b == b'\n')
                        .map_or(bytes.len(), |p| at + p);
                    bytes.drain(start..end);
                }
            }
        }

        std::fs::write(&open_path, &bytes).map_err(|e| e.to_string())?;
        let open_verdict = match Journal::open(&open_path, SyncPolicy::Os) {
            Ok((_, evs)) => Ok(evs.iter().map(|e| format!("{e:?}")).collect::<Vec<_>>()),
            Err(_) => Err(()),
        };
        std::fs::write(&ra_path, &bytes).map_err(|e| e.to_string())?;
        let ra_verdict = match padded.read_all() {
            Ok(evs) => Ok(evs.iter().map(|e| format!("{e:?}")).collect::<Vec<_>>()),
            Err(_) => Err(()),
        };
        if open_verdict == ra_verdict {
            Ok(())
        } else {
            Err(format!(
                "recovery paths diverged (open {:?} vs read_all {:?}) on stream {:?}",
                open_verdict.as_ref().map(Vec::len),
                ra_verdict.as_ref().map(Vec::len),
                String::from_utf8_lossy(&bytes),
            ))
        }
    });

    drop(padded);
    for p in [&base_path, &open_path, &ra_path] {
        let _ = std::fs::remove_file(p);
    }
}

/// Random mutations of valid emissions: flip/delete/insert one byte and
/// require parse to either succeed (the mutation may be harmless, e.g.
/// inside a string) or return Err — never panic.
#[test]
fn random_single_byte_mutations_never_panic() {
    forall("mutated frames parse totally", 500, |g| {
        let tree = gen_value(g, 4);
        let mut bytes = tree.to_string().into_bytes();
        if bytes.is_empty() {
            return Ok(());
        }
        let at = g.rng.below(bytes.len());
        match g.rng.below(3) {
            0 => bytes[at] = (g.rng.next_u64() & 0x7f) as u8,
            1 => {
                bytes.remove(at);
            }
            _ => bytes.insert(at, b"{}[]\",:x01"[g.rng.below(10)]),
        }
        // Mutation can produce invalid UTF-8; only valid strings reach
        // the parser in production (lines are checked first).
        if let Ok(text) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(text); // must not panic
        }
        Ok(())
    });
}
