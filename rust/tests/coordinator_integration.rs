//! Coordinator integration: concurrent BO studies sharing routed,
//! batch-coalescing evaluation workers — with property tests on the
//! routing/batching/state invariants.

use dbe_bo::batcheval::{BatchAcqEvaluator, SyntheticEvaluator};
use dbe_bo::bbob::{self, Objective};
use dbe_bo::coordinator::{BatchService, Router, ServiceConfig};
use dbe_bo::optim::lbfgsb::LbfgsbOptions;
use dbe_bo::optim::mso::{run_mso, MsoConfig, MsoStrategy};
use dbe_bo::rng::Pcg64;
use dbe_bo::testing::forall;
use std::time::Duration;

fn spawn_worker(dim: usize, cfg: ServiceConfig) -> (BatchService, std::thread::JoinHandle<()>) {
    BatchService::spawn(
        Box::new(SyntheticEvaluator::new(Box::new(bbob::Rosenbrock::new(dim)))),
        cfg,
    )
}

#[test]
fn concurrent_mso_through_shared_service_matches_direct() {
    // Many threads run D-BE through ONE coalescing service; results must
    // equal a direct (no-service) run restart-for-restart.
    let d = 4;
    let (svc, handle) = spawn_worker(
        d,
        ServiceConfig { max_batch: 32, max_wait: Duration::from_micros(500) },
    );
    let cfg = MsoConfig {
        bounds: vec![(0.0, 3.0); d],
        lbfgsb: LbfgsbOptions { pgtol: 1e-8, ftol: 0.0, ..Default::default() },
    };

    let mut joins = Vec::new();
    for t in 0..6u64 {
        let svc = svc.clone();
        let cfg = cfg.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Pcg64::seeded(500 + t);
            let x0s: Vec<Vec<f64>> = (0..4).map(|_| rng.uniform_vec(d, 0.0, 3.0)).collect();
            let via_service = run_mso(MsoStrategy::Dbe, &svc, &x0s, &cfg).unwrap();
            // Direct run for comparison (deterministic oracle).
            let direct_ev = SyntheticEvaluator::new(Box::new(bbob::Rosenbrock::new(d)));
            let direct = run_mso(MsoStrategy::Dbe, &direct_ev, &x0s, &cfg).unwrap();
            for (a, b) in via_service.restarts.iter().zip(&direct.restarts) {
                assert_eq!(a.x, b.x, "service must not perturb trajectories");
                assert_eq!(a.iters, b.iters);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let snap = svc.metrics.snapshot();
    assert!(snap.points > 0);
    drop(svc);
    handle.join().unwrap();
}

#[test]
fn router_spreads_load_and_preserves_answers() {
    let d = 3;
    let mut workers = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..3 {
        let (svc, h) = spawn_worker(d, ServiceConfig::default());
        workers.push(svc);
        handles.push(h);
    }
    let router = Router::new(workers.clone()).unwrap();
    let f = bbob::Rosenbrock::new(d);

    let mut rng = Pcg64::seeded(42);
    for _ in 0..60 {
        let p = rng.uniform_vec(d, 0.0, 3.0);
        let (vals, grads) = router.eval_batch(std::slice::from_ref(&p)).unwrap();
        let (v, g) = f.value_grad(&p);
        assert_eq!(vals[0], v);
        assert_eq!(grads[0], g);
    }
    let loads = router.worker_points();
    assert_eq!(loads.iter().sum::<u64>(), 60);
    assert!(
        loads.iter().all(|&l| l > 0),
        "every worker should receive traffic: {loads:?}"
    );
    drop(router);
    drop(workers);
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn property_coalescing_never_drops_or_duplicates() {
    // For any mix of client batch sizes and service knobs, the total
    // number of points the oracle sees equals the number submitted, and
    // every reply is correct and correctly sized.
    forall("no drop/dup under coalescing", 8, |g| {
        let d = 2;
        let max_batch = g.size(12);
        let (svc, handle) = spawn_worker(
            d,
            ServiceConfig {
                max_batch,
                max_wait: Duration::from_micros(200),
            },
        );
        let n_threads = g.size(6);
        let sizes: Vec<usize> = (0..n_threads).map(|_| g.size(4)).collect();
        let total: usize = sizes.iter().sum::<usize>() * 10;

        let mut joins = Vec::new();
        for (t, &k) in sizes.iter().enumerate() {
            let svc = svc.clone();
            joins.push(std::thread::spawn(move || -> Result<(), String> {
                let f = bbob::Rosenbrock::new(d);
                let mut rng = Pcg64::seeded(900 + t as u64);
                for _ in 0..10 {
                    let pts: Vec<Vec<f64>> =
                        (0..k).map(|_| rng.uniform_vec(d, 0.0, 3.0)).collect();
                    let (vals, _) = svc.eval(pts.clone()).map_err(|e| e.to_string())?;
                    if vals.len() != k {
                        return Err(format!("got {} values for {k} points", vals.len()));
                    }
                    for (i, p) in pts.iter().enumerate() {
                        if vals[i] != f.value(p) {
                            return Err("wrong value".into());
                        }
                    }
                }
                Ok(())
            }));
        }
        for j in joins {
            j.join().map_err(|_| "panic".to_string())??;
        }
        let snap = svc.metrics.snapshot();
        if snap.points as usize != total {
            return Err(format!("oracle saw {} points, submitted {total}", snap.points));
        }
        drop(svc);
        handle.join().map_err(|_| "worker panic".to_string())?;
        Ok(())
    });
}

#[test]
fn service_shutdown_is_clean() {
    let (svc, handle) = spawn_worker(2, ServiceConfig::default());
    let _ = svc.eval(vec![vec![1.0, 1.0]]).unwrap();
    drop(svc); // all senders gone → worker exits
    handle.join().unwrap();
}
