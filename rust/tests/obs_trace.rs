//! End-to-end flight-recorder battery (ISSUE 9): drive a live loopback
//! `dbe-bo serve` with the recorder armed over the wire and assert the
//! dumped Chrome trace JSON carries spans from every layer of the ask
//! path — serve frame handling, hub actor dispatch, pool coalescing,
//! the MSO QN loop, GP fits, and the journal. Also pins the trace-event
//! invariants Perfetto needs: every Begin has a matching End on the
//! same thread, timestamps are non-decreasing per thread, and instants
//! are thread-scoped.

use dbe_bo::bo::StudyConfig;
use dbe_bo::hub::json::Json;
use dbe_bo::hub::{HubClient, HubConfig, ServeConfig, Server, StudyHub, StudySpec};
use dbe_bo::obs::recorder;
use dbe_bo::optim::mso::MsoStrategy;
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::Arc;

fn quick_cfg() -> StudyConfig {
    StudyConfig {
        dim: 2,
        bounds: vec![(-5.0, 5.0); 2],
        n_trials: 40,
        n_startup: 4,
        restarts: 3,
        strategy: MsoStrategy::Dbe,
        fit_every: 2,
        ..StudyConfig::default()
    }
}

fn bowl(x: &[f64]) -> f64 {
    (x[0] - 0.5).powi(2) + (x[1] + 1.0).powi(2)
}

fn temp_journal(tag: &str) -> PathBuf {
    let path = std::env::temp_dir()
        .join(format!("dbe_bo_obs_{tag}_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    path
}

#[test]
fn live_trace_covers_every_layer_of_the_ask_path() {
    let _g = recorder::exclusive();
    let path = temp_journal("live");

    // Journal + pool so all five layers are actually on the path.
    let hub = Arc::new(
        StudyHub::open(HubConfig {
            journal: Some(path.clone()),
            pool_workers: 2,
            ..HubConfig::default()
        })
        .unwrap(),
    );
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        ..ServeConfig::default()
    })
    .unwrap();
    server.install_hub(Arc::clone(&hub));
    let addr = server.local_addr().to_string();

    let mut client = HubClient::connect(&addr).unwrap();
    // Arm over the wire — exactly what `dbe-bo client --trace` sends.
    client.trace_arm(true).unwrap();
    client.create(&StudySpec::new("s", quick_cfg(), 17)).unwrap();

    // Drive well past n_startup so acquisition (mso/gp/pool) runs.
    let mut done = 0usize;
    while done < 12 {
        let batch = client.ask("s", 2).unwrap();
        for sug in batch {
            client.tell("s", sug.trial_id, bowl(&sug.x)).unwrap();
            done += 1;
        }
    }

    let trace = client.trace_dump().unwrap();
    let emitted = client.trace_arm(false).unwrap();
    client.shutdown().unwrap();
    drop(client);
    server.join();
    let _ = std::fs::remove_file(&path);

    // The dump must be exactly what --trace-out writes: re-parse it.
    let text = trace.to_string();
    let back = Json::parse(&text).expect("trace JSON must round-trip");
    let events = back.field("traceEvents").unwrap().as_arr().unwrap().clone();
    assert!(events.len() > 20, "a 12-trial run must record real work");

    // Acceptance: spans from all five layers (plus gp) in one trace.
    let cats: HashSet<String> = events
        .iter()
        .map(|e| e.field("cat").unwrap().as_str().unwrap().to_string())
        .collect();
    for layer in ["serve", "hub", "pool", "mso", "gp", "journal"] {
        assert!(cats.contains(layer), "layer {layer} missing from trace: {cats:?}");
    }

    // Per-thread trace-event invariants: balanced B/E nesting and
    // non-decreasing timestamps (what chrome://tracing validates). A
    // wrapped ring legitimately loses old Begin events, so the strict
    // nesting check only applies when every emitted event survived.
    let wrapped = emitted > recorder::RING_CAP as u64;
    let mut depth: HashMap<u64, i64> = HashMap::new();
    let mut last_ts: HashMap<u64, f64> = HashMap::new();
    for e in &events {
        let tid = e.field("tid").unwrap().as_u64().unwrap();
        let ts = e.field("ts").unwrap().as_f64().unwrap();
        let prev = last_ts.entry(tid).or_insert(ts);
        assert!(*prev <= ts, "timestamps must be non-decreasing per thread");
        *prev = ts;
        match e.field("ph").unwrap().as_str().unwrap() {
            "B" => *depth.entry(tid).or_insert(0) += 1,
            "E" => {
                let d = depth.entry(tid).or_insert(0);
                *d -= 1;
                assert!(wrapped || *d >= 0, "E without a matching B on tid {tid}");
            }
            "i" => {
                assert_eq!(
                    e.field("s").unwrap().as_str().unwrap(),
                    "t",
                    "instants are thread-scoped"
                );
            }
            ph => panic!("unexpected phase {ph}"),
        }
    }

    // The per-restart QN telemetry the paper's tables are built from
    // must be present and well-formed on at least one event.
    let qn = events
        .iter()
        .find(|e| e.field("name").unwrap().as_str().unwrap() == "qn_restart")
        .expect("D-BE run must emit mso/qn_restart instants");
    let args = qn.field("args").unwrap();
    assert!(args.field("iters").unwrap().as_u64().unwrap() >= 1);
    assert!(args.field("grad_inf").unwrap().as_f64().unwrap().is_finite());
    let reason = args.field("reason").unwrap().as_str().unwrap();
    assert!(
        ["gradtol", "ftol", "max_iters", "max_evals", "linesearch", "numerical"]
            .contains(&reason),
        "unknown stop reason {reason}"
    );
}

/// Disarmed is the default: a full serve lifecycle without `--record`
/// or a `trace` arm must leave the ring untouched, and a dump must
/// answer an empty (but valid) trace rather than an error.
#[test]
fn disarmed_serve_records_nothing_and_dumps_empty_trace() {
    let _g = recorder::exclusive();

    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        ..ServeConfig::default()
    })
    .unwrap();
    server.install_hub(Arc::new(StudyHub::in_memory()));
    let addr = server.local_addr().to_string();

    let mut client = HubClient::connect(&addr).unwrap();
    client.create(&StudySpec::new("s", quick_cfg(), 5)).unwrap();
    let batch = client.ask("s", 2).unwrap();
    for sug in batch {
        client.tell("s", sug.trial_id, bowl(&sug.x)).unwrap();
    }

    let trace = client.trace_dump().unwrap();
    assert!(
        trace.field("traceEvents").unwrap().as_arr().unwrap().is_empty(),
        "disarmed recorder must stay empty"
    );
    assert_eq!(recorder::emitted(), 0, "no events may be emitted while disarmed");

    client.shutdown().unwrap();
    drop(client);
    server.join();
}
