"""Tiny scipy-free normal pdf/cdf for test oracles (scipy may be absent)."""

import math


def norm_pdf(z: float) -> float:
    return math.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


def norm_cdf(z: float) -> float:
    return 0.5 * math.erfc(-z / math.sqrt(2.0))
