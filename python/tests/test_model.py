"""L2 correctness: the acquisition/MLL model the artifacts are lowered from."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402
from scipy_free_stats import norm_cdf, norm_pdf  # noqa: E402


def _gp_problem(seed, n, d, n_pad):
    """Build a random GP state exactly the way the Rust side would:
    kernel over real rows, Cholesky, alpha, then identity-padding."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.0, 1.0, size=(n, d))
    y = np.sin(3.0 * x[:, 0]) + 0.1 * rng.standard_normal(n)
    y = (y - y.mean()) / max(y.std(), 1e-12)
    log_len, log_sf2, log_noise = -0.8, 0.1, -6.0

    k = np.asarray(ref.ref_matern52_gram(jnp.asarray(x), log_len, log_sf2, log_noise))
    alpha = np.linalg.solve(k, y)

    x_pad = np.zeros((n_pad, d))
    x_pad[:n] = x
    mask = np.zeros(n_pad)
    mask[:n] = 1.0
    kinv_pad = np.zeros((n_pad, n_pad))
    kinv_pad[:n, :n] = np.linalg.inv(k)
    a_pad = np.zeros(n_pad)
    a_pad[:n] = alpha
    params = np.array([log_len, log_sf2, log_noise, float(y.min())])
    return (
        jnp.asarray(x_pad),
        jnp.asarray(mask),
        jnp.asarray(kinv_pad),
        jnp.asarray(a_pad),
        jnp.asarray(params),
        x,
        y,
        k,
    )


def _numpy_neg_logei(q, x, y, k, params):
    """Fully independent numpy implementation (no shared code)."""
    log_len, log_sf2, _, f_best = params
    a = np.sqrt(5.0) / np.exp(log_len)
    sf2 = np.exp(log_sf2)
    r = np.linalg.norm(x - q[None, :], axis=1)
    kstar = sf2 * (1.0 + a * r + (a * r) ** 2 / 3.0) * np.exp(-a * r)
    kinv_y = np.linalg.solve(k, y)
    mean = kstar @ kinv_y
    var = max(sf2 - kstar @ np.linalg.solve(k, kstar), 1e-18)
    sigma = np.sqrt(var)
    z = (f_best - mean) / sigma
    h = norm_pdf(z) + z * norm_cdf(z)
    return -(np.log(sigma) + np.log(max(h, 1e-300)))


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 1000),
    n=st.integers(3, 20),
    d=st.integers(1, 4),
)
def test_acq_value_matches_numpy(seed, n, d):
    n_pad = 32
    x_pad, mask, kinv_pad, a_pad, params, x, y, k = _gp_problem(seed, n, d, n_pad)
    rng = np.random.default_rng(seed + 1)
    q = jnp.asarray(rng.uniform(0.0, 1.0, size=(4, d)))
    vals, grads = model.acq_value_and_grad(q, x_pad, mask, kinv_pad, a_pad, params)
    assert vals.shape == (4,)
    assert grads.shape == (4, d)
    for i in range(4):
        want = _numpy_neg_logei(np.asarray(q[i]), x, y, k, np.asarray(params))
        # The naive numpy oracle computes h = φ + zΦ directly, which
        # cancels catastrophically once z ≲ −6 (|val| ≳ 20); only our
        # log-domain implementation is accurate there. Compare tightly
        # in the oracle's reliable range, loosely in its marginal range.
        if abs(want) < 20:
            np.testing.assert_allclose(vals[i], want, rtol=1e-8, atol=1e-8)
        elif abs(want) < 60:
            np.testing.assert_allclose(vals[i], want, rtol=1e-4, atol=1e-4)


def test_acq_grad_matches_fd():
    n_pad = 32
    x_pad, mask, kinv_pad, a_pad, params, *_ = _gp_problem(7, 12, 3, n_pad)
    q0 = jnp.asarray(np.random.default_rng(8).uniform(0.2, 0.8, size=(3, 3)))
    vals, grads = model.acq_value_and_grad(q0, x_pad, mask, kinv_pad, a_pad, params)
    h = 1e-6
    for b in range(3):
        for i in range(3):
            qp = q0.at[b, i].add(h)
            qm = q0.at[b, i].add(-h)
            vp, _ = model.acq_value_and_grad(qp, x_pad, mask, kinv_pad, a_pad, params)
            vm, _ = model.acq_value_and_grad(qm, x_pad, mask, kinv_pad, a_pad, params)
            fd = (vp[b] - vm[b]) / (2 * h)
            np.testing.assert_allclose(grads[b, i], fd, rtol=2e-4, atol=2e-4)


def test_mask_invariance():
    """Padding to a larger bucket must not change values or gradients."""
    for n_pad in (16, 32, 64):
        x_pad, mask, kinv_pad, a_pad, params, *_ = _gp_problem(3, 9, 2, n_pad)
        q = jnp.asarray(np.random.default_rng(4).uniform(0.0, 1.0, size=(5, 2)))
        vals, grads = model.acq_value_and_grad(q, x_pad, mask, kinv_pad, a_pad, params)
        if n_pad == 16:
            base_vals, base_grads = np.asarray(vals), np.asarray(grads)
        else:
            np.testing.assert_allclose(vals, base_vals, rtol=1e-12, atol=1e-12)
            np.testing.assert_allclose(grads, base_grads, rtol=1e-10, atol=1e-10)


def test_log_h_stability_deep_tail():
    zs = jnp.asarray([-500.0, -50.0, -8.5, -3.0, -1.0, 0.0, 3.0])
    out = model.log_h(zs)
    assert bool(jnp.all(jnp.isfinite(out)))
    # Spot-check direct region.
    np.testing.assert_allclose(
        out[-1], np.log(norm_pdf(3.0) + 3.0 * norm_cdf(3.0)), rtol=1e-10
    )


def test_mll_grad_matches_fd():
    n_pad = 32
    x_pad, mask, kinv_pad, a_pad, params, x, y, k = _gp_problem(11, 14, 2, n_pad)
    y_pad = jnp.zeros(n_pad).at[: len(y)].set(jnp.asarray(y))
    theta = jnp.asarray([-0.5, 0.2, -4.0])
    val, grad = model.mll_value_and_grad(theta, x_pad, mask, y_pad)
    assert np.isfinite(val)
    h = 1e-6
    for i in range(3):
        tp = theta.at[i].add(h)
        tm = theta.at[i].add(-h)
        vp, _ = model.mll_value_and_grad(tp, x_pad, mask, y_pad)
        vm, _ = model.mll_value_and_grad(tm, x_pad, mask, y_pad)
        fd = (vp - vm) / (2 * h)
        np.testing.assert_allclose(grad[i], fd, rtol=1e-5, atol=1e-6)


def test_mll_mask_invariance():
    x_pad16, mask16, _, _, _, x, y, _ = _gp_problem(5, 10, 2, 16)
    x_pad64 = jnp.zeros((64, 2)).at[:10].set(jnp.asarray(x))
    mask64 = jnp.zeros(64).at[:10].set(1.0)
    y16 = jnp.zeros(16).at[:10].set(jnp.asarray(y))
    y64 = jnp.zeros(64).at[:10].set(jnp.asarray(y))
    theta = jnp.asarray([-0.3, 0.0, -5.0])
    v16, g16 = model.mll_value_and_grad(theta, x_pad16, mask16, y16)
    v64, g64 = model.mll_value_and_grad(theta, x_pad64, mask64, y64)
    np.testing.assert_allclose(v16, v64, rtol=1e-10)
    np.testing.assert_allclose(g16, g64, rtol=1e-8, atol=1e-10)
