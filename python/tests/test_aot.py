"""AOT lowering sanity: HLO text parses structurally and the manifest is
consistent. (Full load-and-execute parity with Rust lives in
rust/tests/pjrt_parity.rs.)"""

import os
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot  # noqa: E402


def test_acq_lowering_produces_hlo_text():
    text = aot.lower_acq(dim=2, n_pad=8, batch=3)
    assert "HloModule" in text
    assert "ENTRY" in text
    # f64 end to end.
    assert "f64" in text
    # Batched output: (3,) values and (3, 2) grads.
    assert "f64[3]" in text
    assert "f64[3,2]" in text


def test_mll_lowering_produces_hlo_text():
    text = aot.lower_mll(dim=2, n_pad=8)
    assert "HloModule" in text
    assert "f64[3]" in text  # gradient w.r.t. 3 hyperparameters


def test_build_writes_manifest_and_is_incremental():
    with tempfile.TemporaryDirectory() as tmp:
        manifest = aot.build(tmp, dims=[2], buckets=[8], batch=3)
        assert len(manifest) == 2  # acq + mll
        files = set(os.listdir(tmp))
        assert "manifest.txt" in files
        assert "acq_d2_n8_b3.hlo.txt" in files
        assert "mll_d2_n8.hlo.txt" in files
        # Incremental: second build must not rewrite (compare mtimes).
        paths = [os.path.join(tmp, f) for f in files]
        mtimes = {p: os.path.getmtime(p) for p in paths if not p.endswith("manifest.txt")}
        aot.build(tmp, dims=[2], buckets=[8], batch=3)
        for p, t in mtimes.items():
            assert os.path.getmtime(p) == t, f"{p} was rewritten"


def test_manifest_format():
    with tempfile.TemporaryDirectory() as tmp:
        aot.build(tmp, dims=[2], buckets=[8], batch=3)
        with open(os.path.join(tmp, "manifest.txt")) as f:
            lines = [l.strip() for l in f if l.strip() and not l.startswith("#")]
        assert len(lines) == 2
        kinds = set()
        for line in lines:
            kind, dim, n_pad, batch, fname = line.split()
            kinds.add(kind)
            assert int(dim) == 2 and int(n_pad) == 8
            assert os.path.exists(os.path.join(tmp, fname))
        assert kinds == {"acq", "mll"}
