"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, dtypes, and hyperparameters; every case must
match `ref.py` to dtype-appropriate tolerance. This is the CORE
correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import matern, ref  # noqa: E402


def _points(rng, n, d, dtype):
    return jnp.asarray(rng.uniform(-2.0, 2.0, size=(n, d)), dtype=dtype)


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 12),
    n=st.integers(1, 70),
    d=st.integers(1, 9),
    log_len=st.floats(-1.5, 1.5),
    log_sf2=st.floats(-1.0, 1.0),
    seed=st.integers(0, 2**16),
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
)
def test_matern_cross_matches_ref(b, n, d, log_len, log_sf2, seed, dtype):
    rng = np.random.default_rng(seed)
    q = _points(rng, b, d, dtype)
    x = _points(rng, n, d, dtype)
    got = matern.matern52_cross(q, x, log_len, log_sf2)
    want = ref.ref_matern52_cross(q, x, log_len, log_sf2)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)
    assert got.shape == (b, n)
    assert got.dtype == dtype


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 50),
    d=st.integers(1, 6),
    log_len=st.floats(-1.0, 1.0),
    log_noise=st.floats(-8.0, -1.0),
    seed=st.integers(0, 2**16),
)
def test_gram_matches_ref(n, d, log_len, log_noise, seed):
    rng = np.random.default_rng(seed)
    x = _points(rng, n, d, jnp.float64)
    got = matern.matern52_gram(x, log_len, 0.3, log_noise)
    want = ref.ref_matern52_gram(x, log_len, 0.3, log_noise)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_cross_shapes_beyond_one_tile():
    """Exercise the multi-tile grid path (n > TILE_N, b > TILE_B)."""
    rng = np.random.default_rng(0)
    q = _points(rng, matern.TILE_B + 5, 3, jnp.float64)
    x = _points(rng, matern.TILE_N + 37, 3, jnp.float64)
    got = matern.matern52_cross(q, x, 0.1, 0.2)
    want = ref.ref_matern52_cross(q, x, 0.1, 0.2)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_cross_diag_equals_signal_variance():
    rng = np.random.default_rng(1)
    x = _points(rng, 8, 4, jnp.float64)
    k = matern.matern52_cross(x, x, -0.3, 0.7)
    np.testing.assert_allclose(np.diag(k), np.exp(0.7), rtol=1e-12)


def test_gram_is_symmetric_psd():
    rng = np.random.default_rng(2)
    x = _points(rng, 24, 3, jnp.float64)
    k = np.asarray(matern.matern52_gram(x, 0.0, 0.0, -4.0))
    np.testing.assert_allclose(k, k.T, rtol=1e-12)
    evals = np.linalg.eigvalsh(k)
    assert evals.min() > 0, f"min eig {evals.min()}"


def test_zero_distance_smoothness():
    """Identical q and x rows: no NaN from sqrt(0) in the gradient path."""
    x = jnp.zeros((3, 2), dtype=jnp.float64)
    g = jax.grad(lambda q: matern.matern52_cross(q, x, 0.0, 0.0).sum())(
        jnp.zeros((2, 2), dtype=jnp.float64)
    )
    assert bool(jnp.all(jnp.isfinite(g)))


@pytest.mark.parametrize("b,n", [(1, 1), (1, 130), (17, 1), (10, 256)])
def test_edge_shapes(b, n):
    rng = np.random.default_rng(3)
    q = _points(rng, b, 5, jnp.float64)
    x = _points(rng, n, 5, jnp.float64)
    got = matern.matern52_cross(q, x, 0.0, 0.0)
    want = ref.ref_matern52_cross(q, x, 0.0, 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
