"""Layer-2 JAX model: GP posterior + LogEI, batched over restarts.

This is the computation the Rust coordinator executes per L-BFGS-B
iteration through the AOT artifact. Design decisions that matter:

* **Precomputed solves as inputs.** The artifact takes ``K⁻¹`` of the
  (real-rows-only, noise-added) kernel matrix and ``alpha = K⁻¹ y``
  from the Rust side, which factorizes K once per GP fit anyway.
  Padded rows carry zeros in ``alpha``/``mask`` (and anything in the
  padded block of ``K⁻¹``), so padding changes nothing (tested in
  ``tests/test_model.py::test_mask_invariance``).
* **Batched value-and-grad in one program.** `jax.vmap(jax.value_and_grad)`
  over the B query rows — the whole point of the paper's batched
  evaluation — so one PJRT execution returns all B values and B×D
  gradients with a shared forward pass structure XLA can fuse.
* **f64.** The Rust L-BFGS-B runs in f64; mixed precision would perturb
  trajectories (the paper's "modulo floating-point nondeterminism"
  caveat). We keep the artifact in f64 end to end.
* The Matérn cross-covariance calls the **Pallas kernel**
  (``kernels.matern``), so Layer 1 lowers into this same HLO.
"""

import jax
import jax.numpy as jnp

from .kernels import matern
from .kernels.ref import ref_matern52_gram

jax.config.update("jax_enable_x64", True)

LOG_2PI = 1.8378770664093453
INV_SQRT_2PI = 0.3989422804014327
SQRT_PI = 1.772453850905516
SQRT_2 = 1.4142135623730951


def log_normal_pdf(z):
    return -0.5 * z * z - 0.5 * LOG_2PI


@jax.custom_vjp
def erfc_hlo(x):
    """Machine-precision erfc built ONLY from primitive HLO ops.

    `jax.scipy.special.erfc` lowers to the dedicated `erf` HLO opcode,
    which the xla_extension 0.5.1 text parser predates — artifacts using
    it fail to load in the Rust runtime. This mirrors the Rust
    implementation (rust/src/gp/stats.rs): Maclaurin series for |x| < 2,
    Lentz continued fraction for x ≥ 2.

    Two compile-time considerations shape the implementation (measured:
    27.7 s → ~1 s artifact compile on xla_extension 0.5.1, §Perf):
    * the iterations run as `lax.fori_loop` (one compact While HLO)
      rather than an unrolled chain of ~128 instruction groups;
    * the derivative is attached analytically via `custom_vjp`
      (erfc′(x) = −2/√π e^{−x²}), so autodiff never transposes the
      loops at all.
    """
    return _erfc_fwd_impl(x)


def _erfc_fwd_impl(x):
    ax = jnp.abs(x)

    # --- series branch (|x| < 2): erf(x) = 2/√π Σ (−x²)ⁿ x /(n!(2n+1))
    xs = jnp.minimum(ax, 2.0)
    x2 = xs * xs

    def series_body(n, carry):
        term, acc = carry
        nf = n.astype(xs.dtype)
        term = term * (-x2) / nf
        return term, acc + term / (2.0 * nf + 1.0)

    _, acc = jax.lax.fori_loop(1, 48, series_body, (xs, xs))
    small = 1.0 - (2.0 / SQRT_PI) * acc

    # --- continued-fraction branch (x ≥ 2):
    # erfc(x) = e^{−x²}/√π / (x + ½/(x + 1/(x + 3/2/(x + …))))
    xc = jnp.clip(ax, 2.0, 30.0)
    tiny = 1e-300

    def cf_body(k, carry):
        f, c, d = carry
        a = k.astype(xc.dtype) / 2.0
        d = xc + a * d
        d = jnp.where(jnp.abs(d) < tiny, tiny, d)
        c = xc + a / c
        c = jnp.where(jnp.abs(c) < tiny, tiny, c)
        d = 1.0 / d
        return f * (c * d), c, d

    f, _, _ = jax.lax.fori_loop(1, 48, cf_body, (xc, xc, jnp.zeros_like(xc)))
    large = jnp.exp(-xc * xc) / (SQRT_PI * f)

    pos = jnp.where(ax < 2.0, small, large)
    return jnp.where(x < 0.0, 2.0 - pos, pos)


def _erfc_fwd(x):
    return _erfc_fwd_impl(x), x


def _erfc_bwd(x, ct):
    # erfc′(x) = −2/√π · e^{−x²}; clamp the exponent so the unselected-
    # branch rule (inf·0) can never produce NaN for extreme inputs.
    x2 = jnp.minimum(x * x, 700.0)
    return (ct * (-2.0 / SQRT_PI) * jnp.exp(-x2),)


erfc_hlo.defvjp(_erfc_fwd, _erfc_bwd)


def log_h(z):
    """Stable log h(z), h(z) = φ(z) + z·Φ(z) (Ament et al. 2023).

    Mirrors the Rust implementation (rust/src/gp/stats.rs): direct
    formula for z > −1, Mills-ratio form in the mid tail, asymptotic
    series in the deep tail (z < −8). Uses [`erfc_hlo`] so the lowered
    artifact contains no `erf` opcode.
    """
    # Large-z region (z > 8): Φ(z) = 1 − O(1e-16), φ(z) ≤ 5e-15, so
    # h(z) = z to machine precision.
    big = jnp.log(jnp.maximum(z, 1e-300))

    # Direct region (−1 < z ≤ 8); input clamped so the unselected branch
    # stays finite for extreme z.
    zd = jnp.clip(z, -2.0, 8.5)
    phi = INV_SQRT_2PI * jnp.exp(-0.5 * zd * zd)
    cdf = 0.5 * erfc_hlo(-zd / SQRT_2)
    direct = jnp.where(
        z > 8.0, big, jnp.log(jnp.maximum(phi + zd * cdf, 1e-300))
    )

    # Mid tail (−30 < z ≤ −1): h = φ(z)(1 + z t), t = Φ/φ. Both Φ and φ
    # stay ≥ ~1e-200 down to z = −30, so the ratio is exact — but it
    # must be formed in the LOG domain: the naive quotient's vjp divides
    # by φ², which underflows past |z| ≈ 26.6 and turns the (zeroed-out,
    # but still computed) branch gradient into inf·0 = NaN.
    zm = jnp.clip(z, -30.5, -1.0)
    t = jnp.exp(
        jnp.log(jnp.maximum(0.5 * erfc_hlo(-zm / SQRT_2), 1e-300))
        - log_normal_pdf(zm)
    )
    one_plus_zt = 1.0 + zm * t
    mid = log_normal_pdf(zm) + jnp.log(jnp.maximum(one_plus_zt, 1e-300))

    # Deep tail (z ≤ −30): h(z) ≈ φ(z)/z² (1 − 3/z² + 15/z⁴); series
    # error ≤ 105/z⁶ ≈ 1.4e-7 at the switch point.
    z_safe = jnp.minimum(z, -1.0)
    iz2 = 1.0 / (z_safe * z_safe)
    deep = (
        log_normal_pdf(z)
        - 2.0 * jnp.log(-z_safe)
        + jnp.log(jnp.maximum(1.0 - 3.0 * iz2 + 15.0 * iz2 * iz2, 1e-300))
    )

    return jnp.where(z > -1.0, direct, jnp.where(z > -30.0, mid, deep))


def posterior_batch(q_batch, x_train, mask, k_inv, alpha, log_len, log_sf2):
    """GP posterior (μ, σ²) at B query points in one shot.

    ONE Pallas cross-covariance call for the whole batch (B, N_pad) —
    this is the paper's batched evaluation — followed by pure GEMMs:
    `μ = K* α`, `σ² = σ_f² − rowsum((K* K⁻¹) ∘ K*)`.

    The precomputed `K⁻¹` comes from the Rust side (which factorizes K
    once per GP fit anyway). A triangular solve against L would be the
    textbook form, but CPU-jax lowers `solve_triangular` to a LAPACK
    typed-FFI custom call that xla_extension 0.5.1 cannot compile — and
    on TPU the GEMM form is what you want regardless (MXU, not a
    sequential substitution).
    """
    kstar = matern.matern52_cross(q_batch, x_train, log_len, log_sf2)
    kstar = kstar * mask[None, :]  # padded rows contribute nothing
    mean = kstar @ alpha  # (B,)
    v = kstar @ k_inv  # (B, N_pad)
    var = jnp.exp(log_sf2) - jnp.sum(v * kstar, axis=1)
    return mean, jnp.maximum(var, 1e-18)


def neg_logei_batch(q_batch, x_train, mask, k_inv, alpha, params):
    """−LogEI at B queries; params = [log_len, log_sf2, log_noise, f_best]."""
    log_len, log_sf2, f_best = params[0], params[1], params[3]
    mean, var = posterior_batch(
        q_batch, x_train, mask, k_inv, alpha, log_len, log_sf2
    )
    sigma = jnp.sqrt(var)
    z = (f_best - mean) / sigma
    return -(jnp.log(sigma) + log_h(z))


def acq_value_and_grad(q_batch, x_train, mask, k_inv, alpha, params):
    """Batched (−LogEI, ∇) over B queries — THE artifact entry point.

    The per-restart gradients come from ONE backward pass through the
    *sum* of the batch values: since restart b's value depends only on
    row b of `q_batch` (eq. 1's additive separability), the gradient of
    the sum w.r.t. `q_batch` has exactly the per-restart gradients as
    rows. This is the same algebraic fact C-BE exploits — used here
    purely for evaluation batching, with the QN updates decoupled on the
    Rust side (the paper's D-BE split).

    Returns (vals (B,), grads (B, D)).
    """

    def summed(q):
        vals = neg_logei_batch(q, x_train, mask, k_inv, alpha, params)
        return jnp.sum(vals), vals

    (_, vals), grads = jax.value_and_grad(summed, has_aux=True)(q_batch)
    return vals, grads


def cholesky_hlo(a):
    """In-graph right-looking Cholesky via `lax.fori_loop` — plain While
    HLO, because `jnp.linalg.cholesky` lowers to a LAPACK FFI custom
    call on CPU that the 0.5.1 runtime cannot compile. O(n) loop steps
    of O(n²) vector work, used only on the (cold) GP-fit path."""
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(k, m):
        dkk = jnp.sqrt(jnp.maximum(m[k, k], 1e-300))
        col = jnp.where(idx > k, m[:, k] / dkk, 0.0)
        m = m - col[:, None] * col[None, :]
        m = m.at[:, k].set(jnp.where(idx > k, col, m[:, k]))
        m = m.at[k, k].set(dkk)
        return m

    m = jax.lax.fori_loop(0, n, body, a)
    # Zero the strict upper triangle (left dirty by the updates).
    return jnp.where(idx[:, None] >= idx[None, :], m, 0.0)


def solve_lower_hlo(l, b):
    """Forward substitution `L y = b` via fori_loop (While HLO)."""
    n = l.shape[0]
    idx = jnp.arange(n)

    def body(i, y):
        s = jnp.sum(jnp.where(idx < i, l[i, :] * y, 0.0))
        return y.at[i].set((b[i] - s) / l[i, i])

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


def solve_lower_t_hlo(l, y):
    """Back substitution `Lᵀ x = y` via fori_loop."""
    n = l.shape[0]
    idx = jnp.arange(n)

    def body(k, x):
        i = n - 1 - k
        s = jnp.sum(jnp.where(idx > i, l[:, i] * x, 0.0))
        return x.at[i].set((y[i] - s) / l[i, i])

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(y))


def mll_value_and_grad(theta, x_train, mask, y_std):
    """GP marginal log likelihood and ∂/∂θ — the GP-fit artifact.

    theta = [log_len, log_sf2, log_noise]. Padded rows are excluded by
    giving them unit diagonal/zero off-diagonal in the masked Gram
    matrix and zero targets, which contributes a constant to the MLL.

    Returns (mll, grad(3,)).
    """

    def mll(t):
        n_pad = x_train.shape[0]
        k = ref_matern52_gram(x_train, t[0], t[1], t[2])
        mm = mask[:, None] * mask[None, :]
        eye = jnp.eye(n_pad, dtype=x_train.dtype)
        k = k * mm + (1.0 - mask)[:, None] * eye * (1.0 - mask)[None, :]
        # In-graph Cholesky + substitutions (no LAPACK custom calls).
        lfac = cholesky_hlo(k)
        ym = y_std * mask
        a = solve_lower_t_hlo(lfac, solve_lower_hlo(lfac, ym))
        n_real = jnp.sum(mask)
        logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(lfac)) * mask)
        return (
            -0.5 * jnp.dot(ym, a)
            - 0.5 * logdet
            - 0.5 * n_real * LOG_2PI
        )

    return jax.value_and_grad(mll)(theta)


def make_acq_fn(n_pad, batch, dim):
    """Shape-specialized acquisition function for AOT lowering."""

    def fn(q_batch, x_train, mask, k_inv, alpha, params):
        return acq_value_and_grad(q_batch, x_train, mask, k_inv, alpha, params)

    specs = (
        jax.ShapeDtypeStruct((batch, dim), jnp.float64),
        jax.ShapeDtypeStruct((n_pad, dim), jnp.float64),
        jax.ShapeDtypeStruct((n_pad,), jnp.float64),
        jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float64),
        jax.ShapeDtypeStruct((n_pad,), jnp.float64),
        jax.ShapeDtypeStruct((4,), jnp.float64),
    )
    return fn, specs


def make_mll_fn(n_pad, dim):
    """Shape-specialized MLL function for AOT lowering."""

    def fn(theta, x_train, mask, y_std):
        return mll_value_and_grad(theta, x_train, mask, y_std)

    specs = (
        jax.ShapeDtypeStruct((3,), jnp.float64),
        jax.ShapeDtypeStruct((n_pad, dim), jnp.float64),
        jax.ShapeDtypeStruct((n_pad,), jnp.float64),
        jax.ShapeDtypeStruct((n_pad,), jnp.float64),
    )
    return fn, specs
