"""Layer-1 Pallas kernels: the acquisition pipeline's compute hot spot.

The single most-executed computation in the whole system is the
Matérn-5/2 cross-covariance k(Q, X) between the B restart queries and
the n training points — O(B·n·D) per L-BFGS-B iteration, inside every
batched acquisition evaluation. This module implements it as a tiled
Pallas kernel plus a Gram-matrix variant for the GP-fit path.

TPU mapping (see EXPERIMENTS.md §Perf): the (B, n) grid is tiled
into VMEM blocks via BlockSpec; the squared distance is computed in its
expanded form ‖q‖² − 2 q·xᵀ + ‖x‖² so the dominant term is a
(B_tile, D) × (D, n_tile) matmul that maps onto the MXU, with the two
norm terms as cheap VPU row/column broadcasts. The paper targets CPU
batching (PyTorch); on TPU the same batching insight becomes "make the
batch dimension an MXU operand".

On this image Pallas must run with ``interpret=True`` (CPU PJRT cannot
execute Mosaic custom-calls); the BlockSpec structure is still what a
real TPU lowering would use, and is what §Perf's VMEM/MXU estimates are
computed from.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT5 = 2.23606797749979

# Hard cutoff on a·r: k < 5e-131 beyond this — numerically invisible,
# but letting exp(−ar) underflow into subnormals costs 10-100× in every
# downstream GEMM on x86 (measured: 33× on the fitted-GP acquisition
# path, EXPERIMENTS.md §Perf). `where` produces exact (fast) zeros.
AR_CUTOFF = 300.0


def _matern_from_ar(ar, sf2):
    """σ²(1 + ar + a²r²/3)e^{−ar} with the subnormal cutoff."""
    safe = jnp.minimum(ar, AR_CUTOFF)
    k = sf2 * (1.0 + safe + safe * safe / 3.0) * jnp.exp(-safe)
    return jnp.where(ar > AR_CUTOFF, 0.0, k)


def _grad_coeff_from_ar(ar, sf2, a):
    """∂k/∂q scalar factor −σ²a²/3 (1+ar)e^{−ar} with the cutoff."""
    safe = jnp.minimum(ar, AR_CUTOFF)
    c = -(sf2 * a * a / 3.0) * (1.0 + safe) * jnp.exp(-safe)
    return jnp.where(ar > AR_CUTOFF, 0.0, c)

# Tile sizes for the (B, N) output grid. B is small (10 restarts) so one
# tile usually covers it; N tiles at 128 keep the X-block (128 × D) plus
# the Q-block and output comfortably inside VMEM for D ≤ 64.
# VMEM estimate per block (f32): (TB·D + TN·D + TB·TN) · 4 bytes
#   = (16·64 + 128·64 + 16·128)·4 ≈ 45 KiB  ≪ 16 MiB VMEM.
TILE_B = 16
TILE_N = 128


def _matern_bwd_dq_kernel(q_ref, x_ref, ct_ref, params_ref, out_ref):
    """Backward pass w.r.t. the queries: one (TILE_B, D) tile of
    dL/dQ = Σ_j ct[b,j] · c(r_bj) · (q_b − x_j),
    with c(r) = −σ² a²/3 (1 + a r) e^{−a r} (the analytic ∂k/∂q factor).

    Each block sees its query tile, the FULL training slab (N ≤ 512 →
    ≤160 KiB f64 in VMEM), and its cotangent rows.
    """
    q = q_ref[...]  # (TB, D)
    x = x_ref[...]  # (N, D)
    ct = ct_ref[...]  # (TB, N)
    a = SQRT5 / jnp.exp(params_ref[0])
    sf2 = jnp.exp(params_ref[1])

    qq = jnp.sum(q * q, axis=-1, keepdims=True)
    xx = jnp.sum(x * x, axis=-1)[None, :]
    cross = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=q.dtype
    )
    d2 = jnp.maximum(qq - 2.0 * cross + xx, 0.0)
    ar = a * jnp.sqrt(d2)
    coeff = _grad_coeff_from_ar(ar, sf2, a)  # (TB, N)
    w = ct * coeff
    # dq_b = Σ_j w[b,j] (q_b − x_j) = (Σ_j w[b,j]) q_b − w @ x
    row_sum = jnp.sum(w, axis=-1, keepdims=True)
    out_ref[...] = row_sum * q - jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=q.dtype
    )


def _matern_tile_kernel(q_ref, x_ref, params_ref, out_ref):
    """One (TILE_B, TILE_N) tile of k(Q, X).

    q_ref: (TILE_B, D) queries in VMEM.
    x_ref: (TILE_N, D) training slab in VMEM.
    params_ref: (2,) [log_len, log_sf2] in SMEM-like memory.
    out_ref: (TILE_B, TILE_N) output tile.
    """
    q = q_ref[...]
    x = x_ref[...]
    a = SQRT5 / jnp.exp(params_ref[0])
    sf2 = jnp.exp(params_ref[1])

    # ‖q−x‖² = ‖q‖² − 2 q xᵀ + ‖x‖²; the q xᵀ term is the MXU matmul.
    qq = jnp.sum(q * q, axis=-1, keepdims=True)  # (TB, 1)
    xx = jnp.sum(x * x, axis=-1)[None, :]  # (1, TN)
    cross = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=q.dtype
    )  # (TB, TN)
    d2 = jnp.maximum(qq - 2.0 * cross + xx, 0.0)
    ar = a * jnp.sqrt(d2)
    out_ref[...] = _matern_from_ar(ar, sf2)


def _matern52_cross_fwd_impl(q, x, log_len, log_sf2):
    b, d = q.shape
    n = x.shape[0]
    dtype = q.dtype

    tb = min(TILE_B, max(b, 1))
    tn = min(TILE_N, max(n, 1))
    grid = (pl.cdiv(b, tb), pl.cdiv(n, tn))

    params = jnp.stack([jnp.asarray(log_len, dtype), jnp.asarray(log_sf2, dtype)])

    return pl.pallas_call(
        _matern_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, x, params)


def _matern52_dq_impl(q, x, ct, log_len, log_sf2):
    """Pallas backward kernel: dL/dQ given cotangent ct = dL/dK (B, N)."""
    b, d = q.shape
    n = x.shape[0]
    dtype = q.dtype
    tb = min(TILE_B, max(b, 1))
    grid = (pl.cdiv(b, tb),)
    params = jnp.stack([jnp.asarray(log_len, dtype), jnp.asarray(log_sf2, dtype)])
    return pl.pallas_call(
        _matern_bwd_dq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), dtype),
        interpret=True,
    )(q, x, ct, params)


@jax.custom_vjp
def matern52_cross(q, x, log_len, log_sf2):
    """Pallas Matérn-5/2 cross-covariance k(Q, X) → (B, N).

    `pallas_call` defines no autodiff rule, so the VJP is attached
    analytically: the query-gradient (the artifact's hot backward path)
    is itself a Pallas kernel; the rarely-used x / hyperparameter
    cotangents are cheap jnp expressions that XLA fuses.
    """
    return _matern52_cross_fwd_impl(q, x, log_len, log_sf2)


def _matern52_cross_fwd(q, x, log_len, log_sf2):
    out = _matern52_cross_fwd_impl(q, x, log_len, log_sf2)
    return out, (q, x, log_len, log_sf2)


def _matern52_cross_bwd(res, ct):
    q, x, log_len, log_sf2 = res
    dq = _matern52_dq_impl(q, x, ct, log_len, log_sf2)

    # Cold-path cotangents in plain jnp (exact, fused by XLA).
    a = SQRT5 / jnp.exp(log_len)
    sf2 = jnp.exp(log_sf2)
    diff = q[:, None, :] - x[None, :, :]  # (B, N, D)
    d2 = jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0)
    r = jnp.sqrt(d2)
    ar = a * r
    coeff = _grad_coeff_from_ar(ar, sf2, a)  # ∂k/∂q factor
    dx = -jnp.einsum("bn,bn,bnd->nd", ct, coeff, diff)
    # ∂k/∂logℓ = σ² a²/3 · r² (1 + a r) e^{−a r}
    ar_safe = jnp.minimum(ar, AR_CUTOFF)
    ear = jnp.exp(-ar_safe)
    dk_dlog_len = jnp.where(
        ar > AR_CUTOFF, 0.0, sf2 * (a * a / 3.0) * d2 * (1.0 + ar_safe) * ear
    )
    dlog_len = jnp.sum(ct * dk_dlog_len)
    k = _matern_from_ar(ar, sf2)
    dlog_sf2 = jnp.sum(ct * k)
    return dq, dx, dlog_len, dlog_sf2


matern52_cross.defvjp(_matern52_cross_fwd, _matern52_cross_bwd)


def _gram_tile_kernel(xi_ref, xj_ref, params_ref, out_ref):
    """One tile of the noisy Gram matrix K(X, X) + σ_n² I.

    The noise is added on the true diagonal only, detected from the
    global tile coordinates.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    xi = xi_ref[...]
    xj = xj_ref[...]
    a = SQRT5 / jnp.exp(params_ref[0])
    sf2 = jnp.exp(params_ref[1])
    noise = jnp.exp(params_ref[2])

    qq = jnp.sum(xi * xi, axis=-1, keepdims=True)
    xx = jnp.sum(xj * xj, axis=-1)[None, :]
    cross = jax.lax.dot_general(
        xi, xj, (((1,), (1,)), ((), ())), preferred_element_type=xi.dtype
    )
    d2 = jnp.maximum(qq - 2.0 * cross + xx, 0.0)
    ar = a * jnp.sqrt(d2)
    k = _matern_from_ar(ar, sf2)

    # Global row/col ids of this tile → diagonal mask.
    tb, tn = out_ref.shape
    rows = i * tb + jax.lax.broadcasted_iota(jnp.int32, (tb, tn), 0)
    cols = j * tn + jax.lax.broadcasted_iota(jnp.int32, (tb, tn), 1)
    out_ref[...] = k + jnp.where(rows == cols, noise, jnp.zeros_like(k))


@functools.partial(jax.jit, static_argnames=())
def matern52_gram(x, log_len, log_sf2, log_noise):
    """Pallas noisy Gram matrix K + σ_n² I → (N, N) (GP-fit path)."""
    n, d = x.shape
    dtype = x.dtype
    tn = min(TILE_N, max(n, 1))
    grid = (pl.cdiv(n, tn), pl.cdiv(n, tn))
    params = jnp.stack(
        [
            jnp.asarray(log_len, dtype),
            jnp.asarray(log_sf2, dtype),
            jnp.asarray(log_noise, dtype),
        ]
    )
    return pl.pallas_call(
        _gram_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tn, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), dtype),
        interpret=True,
    )(x, x, params)
