"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this
package must match its `ref_*` twin to float tolerance (enforced by
``python/tests/test_kernels.py`` with hypothesis sweeps over shapes and
dtypes). The L2 model (``compile.model``) calls the Pallas versions so
the kernels lower into the AOT artifact; the refs never ship.
"""

import jax.numpy as jnp

SQRT5 = 2.23606797749979


def ref_sqdist(q, x):
    """Pairwise squared Euclidean distances.

    Args:
      q: (B, D) query points.
      x: (N, D) reference points.

    Returns:
      (B, N) matrix of squared distances.
    """
    # Expanded form ‖q‖² − 2 q·x + ‖x‖² (the MXU-friendly formulation the
    # Pallas kernel uses), clipped at zero against cancellation.
    qq = jnp.sum(q * q, axis=-1, keepdims=True)  # (B, 1)
    xx = jnp.sum(x * x, axis=-1)  # (N,)
    d2 = qq - 2.0 * q @ x.T + xx[None, :]
    return jnp.maximum(d2, 0.0)


def ref_matern52_cross(q, x, log_len, log_sf2):
    """Matérn-5/2 cross-covariance k(Q, X).

    k(r) = σ_f² (1 + a r + a²r²/3) exp(−a r),  a = √5/ℓ.

    Args:
      q: (B, D) queries.
      x: (N, D) training points.
      log_len, log_sf2: scalar log hyperparameters.

    Returns:
      (B, N) covariance matrix.
    """
    a = SQRT5 / jnp.exp(log_len)
    sf2 = jnp.exp(log_sf2)
    r = jnp.sqrt(ref_sqdist(q, x))
    ar = a * r
    # Same subnormal cutoff as the Pallas kernel and the Rust engine
    # (kernels/matern.py AR_CUTOFF): k < 5e-131 becomes an exact zero.
    ar_safe = jnp.minimum(ar, 300.0)
    k = sf2 * (1.0 + ar_safe + ar_safe * ar_safe / 3.0) * jnp.exp(-ar_safe)
    return jnp.where(ar > 300.0, 0.0, k)


def ref_matern52_gram(x, log_len, log_sf2, log_noise):
    """Noisy Matérn-5/2 Gram matrix K + σ_n² I over training points."""
    k = ref_matern52_cross(x, x, log_len, log_sf2)
    return k + jnp.exp(log_noise) * jnp.eye(x.shape[0], dtype=x.dtype)
