"""AOT lowering: JAX model → HLO *text* artifacts for the Rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly.

Artifacts (one per shape bucket, since PJRT executables are
static-shape):

  artifacts/acq_d{D}_n{N}_b{B}.hlo.txt   — batched −LogEI value+grad
  artifacts/mll_d{D}_n{N}.hlo.txt        — GP MLL value+grad
  artifacts/manifest.txt                 — "kind dim n_pad batch file" rows

The Rust side (rust/src/runtime/manifest.rs) reads manifest.txt, picks
the smallest bucket with n_pad ≥ n_train, and pads inputs.

Usage: python -m compile.aot --out-dir ../artifacts \
          [--dims 2,5] [--buckets 32,64,128] [--batch 10]
"""

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_acq(dim: int, n_pad: int, batch: int) -> str:
    fn, specs = model.make_acq_fn(n_pad, batch, dim)
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_mll(dim: int, n_pad: int) -> str:
    fn, specs = model.make_mll_fn(n_pad, dim)
    return to_hlo_text(jax.jit(fn).lower(*specs))


def build(out_dir: str, dims, buckets, batch: int) -> list:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for d in dims:
        for n_pad in buckets:
            name = f"acq_d{d}_n{n_pad}_b{batch}.hlo.txt"
            path = os.path.join(out_dir, name)
            if not os.path.exists(path):
                text = lower_acq(d, n_pad, batch)
                with open(path, "w") as f:
                    f.write(text)
                print(f"  wrote {name} ({len(text) / 1024:.0f} KiB)")
            manifest.append(("acq", d, n_pad, batch, name))

            mname = f"mll_d{d}_n{n_pad}.hlo.txt"
            mpath = os.path.join(out_dir, mname)
            if not os.path.exists(mpath):
                text = lower_mll(d, n_pad)
                with open(mpath, "w") as f:
                    f.write(text)
                print(f"  wrote {mname} ({len(text) / 1024:.0f} KiB)")
            manifest.append(("mll", d, n_pad, 0, mname))

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("# kind dim n_pad batch file\n")
        for row in manifest:
            f.write(" ".join(str(v) for v in row) + "\n")
    return manifest


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--dims", default="2,5")
    p.add_argument("--buckets", default="32,64,128")
    p.add_argument("--batch", type=int, default=10)
    args = p.parse_args()

    dims = [int(v) for v in args.dims.split(",") if v]
    buckets = sorted(int(v) for v in args.buckets.split(",") if v)
    print(f"AOT-lowering acq/mll artifacts: dims={dims} buckets={buckets} B={args.batch}")
    manifest = build(args.out_dir, dims, buckets, args.batch)
    print(f"manifest: {len(manifest)} artifacts in {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
