use dbe_bo::batcheval::{BatchAcqEvaluator, NativeGpEvaluator};
use dbe_bo::gp::{GpParams, GpRegressor};
use dbe_bo::rng::Pcg64;
use dbe_bo::runtime::{Manifest, PjrtEvaluator, PjrtRuntime};
use std::path::Path;

fn main() {
    let (n, d, seed) = (30usize, 2usize, 2u64);
    let mut rng = Pcg64::seeded(seed);
    let x: Vec<Vec<f64>> = (0..n).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
    let y: Vec<f64> = x.iter().map(|p| {
        let s: f64 = p.iter().map(|v| (v - 0.4).powi(2)).sum();
        s + 0.05 * (7.0 * p[0]).sin()
    }).collect();
    let gp = GpRegressor::fit(x, &y, GpParams::default()).unwrap();
    println!("params: len={} sf2={} noise={}", gp.params.lengthscale(), gp.params.signal_var(), gp.params.noise_var());
    let native = NativeGpEvaluator::new(&gp);
    let manifest = Manifest::load(Path::new("artifacts")).unwrap();
    let runtime = PjrtRuntime::cpu().unwrap();
    let pjrt = PjrtEvaluator::from_gp(&runtime, &manifest, &gp).unwrap();
    let mut rng = Pcg64::seeded(100 + seed);
    let qs: Vec<Vec<f64>> = (0..10).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
    let (nv, _) = native.eval_batch(&qs).unwrap();
    let (pv, _) = pjrt.eval_batch(&qs).unwrap();
    for i in 0..10 {
        let p = gp.posterior(&qs[i]);
        let sigma = p.var.sqrt();
        let z = (gp.best_y_std() - p.mean) / sigma;
        println!("q{i}: native={:.6} pjrt={:.6} | mu={:.6e} var={:.6e} z={:.3}", nv[i], pv[i], p.mean, p.var, z);
    }
}
