# Entry points referenced throughout the docs and source comments.
# The Rust side is self-contained; `artifacts` needs a JAX-capable
# Python environment and is only required for the PJRT hot path.

.PHONY: build test lint docs chaos bench bench-smoke bench-gp-fit serve-smoke compact-smoke obs-smoke health-smoke artifacts

build:
	cargo build --release

test:
	cargo test -q

# CI's lint gate: formatting and a warning-clean clippy pass (the
# allow-list for style lints lives in Cargo.toml [lints.clippy]).
lint:
	cargo fmt --check
	cargo clippy --all-targets -- -D warnings

# CI's chaos gate: the crash-only battery plus the supervised-spawn
# source lint (no bare std::thread::spawn inside the hub).
chaos:
	cargo test --release --test chaos
	! grep -rn "std::thread::spawn" rust/src/hub/

# CI's docs gate: rustdoc must be warning-clean and doctests must pass.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	cargo test --doc

bench:
	cargo bench --bench mso_strategies
	cargo bench --bench batched_eval
	cargo bench --bench lbfgsb_update
	cargo bench --bench table_rastrigin
	cargo bench --bench par_dbe
	cargo bench --bench gp_fit
	cargo bench --bench hub_throughput
	cargo bench --bench serve_throughput
	cargo bench --bench journal_replay
	cargo bench --bench health_overhead

# Tiny-budget pass over every bench target so bench code can't rot
# (mirrors CI's bench-smoke job).
bench-smoke:
	cargo bench --bench mso_strategies -- --smoke
	cargo bench --bench batched_eval -- --smoke
	cargo bench --bench lbfgsb_update -- --smoke
	cargo bench --bench table_rastrigin -- --smoke
	cargo bench --bench par_dbe -- --smoke
	cargo bench --bench gp_fit -- --smoke
	cargo bench --bench hub_throughput -- --smoke
	cargo bench --bench serve_throughput -- --smoke
	cargo bench --bench journal_replay -- --smoke
	cargo bench --bench obs_overhead -- --smoke
	cargo bench --bench health_overhead -- --smoke

# The end-to-end serving smoke: loopback clients drive `dbe-bo serve`
# over real TCP and emit results/BENCH_serve.json (asks/sec, ask-RTT
# p50/p99). Mirrors CI's serve-smoke job; run without --smoke on a
# quiet host for real numbers (EXPERIMENTS.md §E2E "Serve").
serve-smoke:
	cargo bench --bench serve_throughput -- --smoke

# The snapshot/compaction smoke: the commit-point chaos test (a crash
# mid-compaction must leave the old segments authoritative) plus the
# tiny-budget replay bench that emits results/BENCH_journal.json.
# Mirrors the compaction steps of CI's chaos-smoke and bench-smoke jobs.
compact-smoke:
	cargo test --release --test chaos mid_compaction
	cargo bench --bench journal_replay -- --smoke

# The observability smoke (ISSUE 9): the flight-recorder/trace
# integration battery plus the overhead bench, which ASSERTS the
# disarmed recorder costs ≤1% of an ask and that arming it never
# changes results. Emits results/BENCH_obs.json; mirrors CI's
# obs-smoke job.
obs-smoke:
	cargo test --release --test obs_trace
	cargo test --release --test chaos armed_flight_recorder
	cargo bench --bench obs_overhead -- --smoke

# The study-health smoke (ISSUE 10): brute-force LOO validation, the
# health-on/off bitwise twin, the `health` wire op battery, the
# no-factorization source lint on the health engine, and the overhead
# bench, which ASSERTS one health update costs ≤5% of an ask. Emits
# results/BENCH_health.json; mirrors CI's health-smoke job.
health-smoke:
	cargo test --release --test fit_engine_equivalence loo_diagnostics
	cargo test --release --test fit_engine_equivalence health_engine
	cargo test --release --test chaos health_engine
	cargo test --release --test serve_protocol health_op
	! grep -inE "cholesky|solve|inverse|with_params" rust/src/obs/health.rs
	cargo bench --bench health_overhead -- --smoke

# The fit-engine perf snapshot: emits results/BENCH_gp_fit.json
# (EXPERIMENTS.md §Perf "GP fit"). Run this on a quiet host for real
# trajectory numbers.
bench-gp-fit:
	cargo bench --bench gp_fit

# AOT-lower the JAX model to HLO text artifacts for the PJRT runtime
# (see python/compile/aot.py and EXPERIMENTS.md §E2E; needs a
# JAX-capable Python environment). Also records the native fit-engine
# perf snapshot when a cargo toolchain is present (best-effort: the
# leading `-` keeps Python-only environments working).
artifacts:
	-cargo bench --bench gp_fit
	cd python && python -m compile.aot --out-dir ../artifacts
