# Entry points referenced throughout the docs and source comments.
# The Rust side is self-contained; `artifacts` needs a JAX-capable
# Python environment and is only required for the PJRT hot path.

.PHONY: build test docs bench artifacts

build:
	cargo build --release

test:
	cargo test -q

# CI's docs gate: rustdoc must be warning-clean and doctests must pass.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	cargo test --doc

bench:
	cargo bench --bench mso_strategies
	cargo bench --bench batched_eval
	cargo bench --bench lbfgsb_update
	cargo bench --bench table_rastrigin
	cargo bench --bench par_dbe

# AOT-lower the JAX model to HLO text artifacts for the PJRT runtime
# (see python/compile/aot.py and EXPERIMENTS.md §E2E).
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts
